"""Partition/halo invariants (the §3 machine model representation)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import partition as part
from repro.graphs import generators as gen


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1, 2, 3, 4]))
def test_partition_edge_cover_and_halo(seed, p):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 40))
    g = gen.random_graph(n, 0.25, seed=seed)
    pg = part.partition_graph(g, p, window_cap=6)
    # every global directed edge appears exactly once with a local row
    seen = set()
    for i in range(p):
        for e in range(pg.E):
            r, c = int(pg.row[i, e]), int(pg.col[i, e])
            if r == pg.nil:
                continue
            gr, gc = int(pg.gid[i, r]), int(pg.gid[i, c])
            if pg.is_local[i, r]:
                key = (gr, gc)
                assert key not in seen
                seen.add(key)
            else:  # reversed cut edge: ghost row -> local col
                assert pg.is_local[i, c]
    src = g.edge_sources()
    assert seen == set(zip(src.tolist(), g.indices.tolist()))
    # ghost board routing is consistent
    for i in range(p):
        for k in range(pg.G):
            if not pg.is_ghost[i, pg.L + k]:
                continue
            o = int(pg.owner_pe[i, pg.L + k])
            slot = int(pg.ghost_owner_slot[i, k])
            lidx = int(pg.iface_slots[o, slot])
            assert int(pg.gid[o, lidx]) == int(pg.gid[i, pg.L + k])


def test_edge_balanced_split_improves_balance():
    g = gen.rhg_like(3000, avg_deg=8, seed=0)
    pg_v = part.partition_graph(g, 8, edge_balanced=False)
    pg_e = part.partition_graph(g, 8, edge_balanced=True)

    def edge_imbalance(pg):
        counts = [(pg.row[i] != pg.nil).sum() for i in range(pg.p)]
        return max(counts) / max(1, np.mean(counts))

    assert edge_imbalance(pg_e) <= edge_imbalance(pg_v) + 1e-9


def test_window_adjacency_bits_exact():
    g = gen.random_graph(25, 0.4, seed=3)
    pg = part.partition_graph(g, 2, window_cap=6)
    for i in range(2):
        es = set()
        for e in range(pg.E):
            r, c = int(pg.row[i, e]), int(pg.col[i, e])
            if r != pg.nil:
                es.add((r, c))
        for v in range(pg.V):
            for a in range(pg.D):
                wa = int(pg.window[i, v, a])
                for b in range(pg.D):
                    wb = int(pg.window[i, v, b])
                    bit = (int(pg.win_adj_bits[i, v, a]) >> b) & 1
                    want = int(
                        a != b and wa != pg.nil and wb != pg.nil
                        and (wa, wb) in es
                    )
                    assert bit == want


def test_pad_to_buckets():
    g = gen.random_graph(10, 0.3, seed=1)
    pg = part.partition_graph(
        g, 2, pad_to=dict(L=32, G=40, E=500, B=16, S=16)
    )
    assert pg.L == 32 and pg.G == 40 and pg.E == 500
    assert pg.B == 16 and pg.S == 16
