"""Checkpointing + fault tolerance: atomic commit, integrity, restart."""

import os

import numpy as np
import pytest

from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.fault import (
    StragglerMonitor, TrainSupervisor, remesh_plan,
)


def _tree():
    return {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "nested": {"b": np.ones(5, dtype=np.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_write=False)
    t = _tree()
    cm.save(3, t)
    got = cm.restore(t)
    np.testing.assert_array_equal(got["a"], t["a"])
    np.testing.assert_array_equal(got["nested"]["b"], t["nested"]["b"])
    assert cm.latest_step() == 3


def test_async_save_and_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_write=True)
    t = _tree()
    for s in range(5):
        t["a"] = t["a"] + 1
        cm.save(s, t)
    cm.wait()
    assert cm.list_steps() == [3, 4]
    got = cm.restore(t, step=4)
    np.testing.assert_array_equal(got["a"], t["a"])


def test_integrity_check_detects_corruption(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_write=False)
    t = _tree()
    path = cm.save(1, t)
    victim = os.path.join(path, "a.npy")
    arr = np.load(victim)
    arr[0, 0] += 1
    np.save(victim, arr)
    with pytest.raises(IOError, match="integrity"):
        cm.restore(t)


def test_partial_write_never_corrupts_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_write=False)
    t = _tree()
    cm.save(1, t)
    # simulate a crashed later save: stray .tmp directory
    os.makedirs(os.path.join(str(tmp_path), "step_000000002.tmp"))
    assert cm.latest_step() == 1
    cm.restore(t)  # must not raise


def test_supervisor_restart_resumes(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_write=False)
    sup = TrainSupervisor(cm, save_every=2)
    calls = []

    def step_fn(state, step):
        calls.append(step)
        return {"a": state["a"] + 1, "nested": state["nested"]}

    state = _tree()
    out = sup.run(state, step_fn, 5, state_template=state)
    assert calls == [0, 1, 2, 3, 4]
    assert float(out["a"][0, 0]) == 5.0

    # restart: resumes from last checkpoint, replays nothing
    calls2 = []
    sup2 = TrainSupervisor(cm, save_every=2)

    def step_fn2(state, step):
        calls2.append(step)
        return {"a": state["a"] + 1, "nested": state["nested"]}

    out2 = sup2.run(_tree(), step_fn2, 7, state_template=_tree())
    assert calls2 == [5, 6]
    assert float(out2["a"][0, 0]) == 7.0


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(alpha=0.5, factor=2.0)
    assert not m.observe(1.0)
    assert not m.observe(1.1)
    assert m.observe(5.0)
    assert m.flagged == 1


def test_remesh_plan_covers_everything():
    plan = remesh_plan(1000, 4, 6)
    for j, segs in enumerate(plan["copies"]):
        covered = sum(s["size"] for s in segs)
        lo = j * 1000 // 6
        hi = (j + 1) * 1000 // 6
        assert covered == hi - lo
