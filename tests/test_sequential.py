"""Sequential baseline (HtWIS-style) correctness: reductions are exact."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import sequential as seq
from repro.core.bitset_mwis import mwis_exact
from repro.core.graph import from_edge_list
from repro.graphs import generators as gen


def _residual_bruteforce(r: seq.SequentialReducer):
    alive = r.alive_vertices()
    if not alive:
        return
    remap = {v: i for i, v in enumerate(alive)}
    edges = [
        (remap[v], remap[u])
        for v in alive for u in r.adj[v] if v < u
    ]
    sub = from_edge_list(
        len(alive), edges, np.array([r.w[v] for v in alive])
    )
    _, msub = mwis_exact(sub)
    for i, v in enumerate(alive):
        r.status[v] = seq.INCLUDED if msub[i] else seq.EXCLUDED


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10_000))
def test_reduce_preserves_alpha(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 13))
    g = gen.random_graph(n, float(rng.uniform(0.05, 0.8)), seed=seed)
    best, _ = mwis_exact(g)
    r = seq.reduce_graph(g)
    _residual_bruteforce(r)
    members = r.reconstruct()
    assert g.is_independent_set(members)
    assert g.set_weight(members) == best


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_reduce_without_folding_preserves_alpha(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 12))
    g = gen.random_graph(n, 0.3, seed=seed + 1)
    best, _ = mwis_exact(g)
    cfg = seq.SeqConfig(use_folding=False)
    r = seq.reduce_graph(g, cfg)
    _residual_bruteforce(r)
    members = r.reconstruct()
    assert g.set_weight(members) == best


def test_solvers_quality_ordering():
    """Paper §7: RnP >= RG >= greedy on reducible instances (on average)."""
    qual = {"rnp": [], "rg": [], "greedy": []}
    for seed in range(6):
        g = gen.rgg2d(300, avg_deg=7, seed=seed)
        best, _ = mwis_exact if False else (None, None)
        w_rnp, _ = seq.solve_reduce_and_peel(g)
        w_rg, _ = seq.solve_reduce_and_greedy(g)
        w_g, _ = seq.solve_greedy(g)
        qual["rnp"].append(w_rnp)
        qual["rg"].append(w_rg)
        qual["greedy"].append(w_g)
    assert np.mean(qual["rnp"]) >= np.mean(qual["rg"]) * 0.999
    assert np.mean(qual["rg"]) >= np.mean(qual["greedy"]) * 0.98


def test_exact_solvers_on_structured_graphs():
    for make, n in ((gen.path_graph, 12), (gen.star_graph, 9)):
        g = make(n, seed=3)
        best, _ = mwis_exact(g)
        w, _ = seq.solve_reduce_and_peel(g)
        # paths and stars reduce completely -> peel never lowers quality
        assert w == best
