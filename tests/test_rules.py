"""Single-PE vectorized rule sweeps: exactness vs brute force (hypothesis)."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import distributed as D
from repro.core import partition as part
from repro.core import rules as R
from repro.core.bitset_mwis import alpha_subset, mwis_exact
from repro.core.local_reduce import reduce_single_pe
from repro.graphs import generators as gen
from tests.helpers import SMALL_PAD, residual_exact_weight


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 100_000))
def test_p1_rules_preserve_alpha(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 13))
    g = gen.random_graph(n, float(rng.uniform(0.05, 0.8)), seed=seed)
    best, _ = mwis_exact(g)
    pg = part.partition_graph(g, 1, window_cap=8, common_cap=4,
                              pad_to=SMALL_PAD)
    state, prob, _ = D.disredu(pg, D.DisReduConfig(heavy_k=6))
    wgt, indep = residual_exact_weight(g, pg, state, prob)
    assert indep and wgt == best


def test_alpha_neighborhood_matches_bitset():
    """The in-JIT 2^K enumeration equals the host bitset solver."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        n = int(rng.integers(2, 10))
        g = gen.random_graph(n, 0.5, seed=trial)
        pg = part.partition_graph(g, 1, window_cap=8, common_cap=4)
        from repro.core.local_reduce import make_aux

        aux = make_aux(pg, pe=0)
        state = R.init_state(
            jnp.asarray(pg.w0[0]), jnp.asarray(pg.is_local[0]),
            jnp.asarray(pg.is_ghost[0]),
        )
        alpha = np.asarray(
            R._alpha_neighborhood(state.w, state.status, aux, 8)
        )
        for v in range(g.n):
            if g.degree(v) > 8:
                continue
            nbrs = g.neighbors(v).tolist()
            k = len(nbrs)
            pos = {u: i for i, u in enumerate(nbrs)}
            bits = np.zeros(k, dtype=np.int64)
            for i, a in enumerate(nbrs):
                for b in g.neighbors(a).tolist():
                    if b in pos:
                        bits[i] |= 1 << pos[b]
            want = alpha_subset(g.weights[nbrs].astype(np.int64), bits)
            assert alpha[v] == want, (trial, v)


def test_exclusion_rules_keep_symmetric_edge():
    """Regression: two equal-weight adjacent vertices must not exclude each
    other in one batch (certificate priority guard)."""
    from repro.core.graph import from_edge_list

    g = from_edge_list(2, [(0, 1)], np.array([5, 5], dtype=np.int32))
    pg = part.partition_graph(g, 1, window_cap=4, common_cap=2)
    state, prob, _ = D.disredu(pg, D.DisReduConfig(heavy_k=4))
    best, _ = mwis_exact(g)
    wgt, indep = residual_exact_weight(g, pg, state, prob)
    assert indep and wgt == best == 5


def test_weight_transfer_chain():
    """Cliques with a light simplicial center exercise WT + reconstruction."""
    from repro.core.graph import from_edge_list

    # triangle {0,1,2} + pendant 3 on vertex 1
    g = from_edge_list(
        4, [(0, 1), (1, 2), (0, 2), (1, 3)],
        np.array([3, 10, 4, 9], dtype=np.int32),
    )
    best, _ = mwis_exact(g)
    pg = part.partition_graph(g, 1, window_cap=4, common_cap=2)
    state, prob, _ = D.disredu(pg, D.DisReduConfig(heavy_k=4))
    wgt, indep = residual_exact_weight(g, pg, state, prob)
    assert indep and wgt == best


def test_fold_log_never_overflows():
    g = gen.path_graph(50, seed=0)
    pg = part.partition_graph(g, 1, window_cap=4, common_cap=2)
    state, prob, _ = D.disredu(pg, D.DisReduConfig(heavy_k=4))
    assert int(state.log_n) <= state.log_kind.shape[0] - 1
    # paths reduce completely
    status = np.asarray(state.status)
    assert (status[np.asarray(prob.is_local)] != R.UNDECIDED).all()
