"""Round-trip property test for ``reconstruct_members``.

Random small graphs → DisRedu to the fixpoint → solve the residual kernel
exactly → replay the fold log — the reconstructed set must be independent
and achieve ``offset`` + the kernel solution's weight + the weight of the
rule-included vertices at their CURRENT (folded-down) weights (the paper's
Theorems 4.x composed: fold bookkeeping loses nothing; include decisions
carry their own weight, and any fold-decrement they absorbed is repaid by
``offset``).  The corpus is chosen so both fold-log record kinds
(LOG_FOLD1 degree-one folds and LOG_WT simplicial weight transfers)
actually replay.
"""

import jax.numpy as jnp
import numpy as np

from repro.core import distributed as D
from repro.core import partition as part
from repro.core import rules as R
from repro.core.bitset_mwis import mwis_exact
from repro.core.graph import from_edge_list
from repro.graphs import generators as gen
from tests.helpers import SMALL_PAD


def _fold_corpus():
    """Graphs that exercise both fold-log record kinds plus random noise."""
    cases = []
    # paths: chains of degree-one folds (LOG_FOLD1)
    cases.append(gen.path_graph(12, seed=0))
    # triangle + pendant with a light simplicial center (LOG_WT)
    cases.append(from_edge_list(
        4, [(0, 1), (1, 2), (0, 2), (1, 3)],
        np.array([3, 10, 4, 9], dtype=np.int32),
    ))
    # clique K4 with a light center vertex attached to all (LOG_WT)
    cases.append(from_edge_list(
        5,
        [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3), (0, 4)],
        np.array([2, 8, 9, 7, 6], dtype=np.int32),
    ))
    for seed in range(8):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(3, 13))
        cases.append(gen.random_graph(n, float(rng.uniform(0.1, 0.6)),
                                      seed=seed))
    return cases


def _round_trip(g, p):
    """Reduce, solve the kernel exactly, replay; return (ok, log_kinds)."""
    pg = part.partition_graph(g, p, window_cap=8, common_cap=4,
                              pad_to=SMALL_PAD)
    state, prob, _ = D.disredu(pg, D.DisReduConfig(heavy_k=6))
    status = np.asarray(state.status)
    w = np.asarray(state.w)
    is_local = np.asarray(prob.is_local)
    gids = np.asarray(prob.aux.gid)

    # exact residual solve under the CURRENT (possibly folded-down) weights
    alive = np.flatnonzero((status == R.UNDECIDED) & is_local)
    alive_g = sorted(set(int(gids[i]) for i in alive))
    remap = {gg: k for k, gg in enumerate(alive_g)}
    row, col = np.asarray(prob.aux.row), np.asarray(prob.aux.col)
    edges = set()
    for e in range(row.shape[0]):
        r, c = int(row[e]), int(col[e])
        if gids[r] < 0 or gids[c] < 0:
            continue
        if status[r] == R.UNDECIDED and status[c] == R.UNDECIDED:
            a, b = int(gids[r]), int(gids[c])
            if a in remap and b in remap and a != b:
                edges.add((min(remap[a], remap[b]), max(remap[a], remap[b])))
    wts = np.zeros(len(alive_g), dtype=np.int64)
    for i in alive:
        wts[remap[int(gids[i])]] = w[i]
    sub = from_edge_list(len(alive_g), sorted(edges), wts)
    kernel_best, msub = mwis_exact(sub)

    # seed the replay with the kernel decision, then replay the fold log
    status2 = status.copy()
    for i in range(status.shape[0]):
        gg = int(gids[i])
        if status[i] == R.UNDECIDED and gg in remap:
            status2[i] = R.INCLUDED if msub[remap[gg]] else R.EXCLUDED
    st2 = state._replace(status=jnp.asarray(status2))
    members = D.members_global(pg, st2, prob.aux)

    assert g.is_independent_set(members), "reconstructed set not independent"
    got = g.set_weight(members)
    included_w = int(w[(status == R.INCLUDED) & is_local].sum())
    want = int(state.offset) + int(kernel_best) + included_w
    assert got == want, \
        f"round-trip weight {got} != offset+kernel+included {want}"

    kinds = set(np.asarray(state.log_kind)[: int(state.log_n)].tolist())
    return kinds


def test_reconstruct_round_trip_covers_both_log_kinds():
    seen = set()
    for g in _fold_corpus():
        for p in (1, 2):
            seen |= _round_trip(g, p)
    assert R.LOG_FOLD1 in seen, "corpus never exercised a degree-one fold"
    assert R.LOG_WT in seen, "corpus never exercised a weight transfer"
