"""Shape descent (adaptive kernel compaction) — bit-identity and policy.

The staged driver's contract: for any graph, PE count, backend, and algo,
``solve_staged`` with descent ON returns the SAME member mask as with
descent OFF (which itself equals the monolithic ``solve``) — compaction is
an exact restriction of the partition and stage chunking visits the same
states as the monolithic while_loops.  These tests pin that contract on
seeded generator families and (when hypothesis is installed) random
GNM/RGG instances, plus the policy pieces around it: the int32 residual
weight gate, descent-tagged plan-cache counters, checkpoint/resume across
a descent boundary, and the serving integration (descent="auto" parity +
oversize admission through the descent entry cells).
"""

import numpy as np
import pytest

from repro.core import distributed as D
from repro.core import engine as E
from repro.core import partition as part
from repro.core import serve as SV
from repro.core import solvers as S
from repro.core import validate as VAL
from repro.graphs.generators import gnm, rgg2d

#: Tiny ladder so descents trigger on test-sized graphs.
TINY_LADDER = tuple(
    S.LadderCell(name=f"t{L}", L=L, E=E, G=max(L // 2, 4),
                 B=max(L // 4, 4), S=max(L // 4, 4))
    for L, E in ((8, 128), (16, 256), (32, 512), (64, 1024), (128, 2048))
)


def _cfgs(backend="jnp", mode="async"):
    base = dict(mode=mode, heavy_k=6, backend=backend)
    return (D.DisReduConfig(**base),
            D.DisReduConfig(**base, descent=True, descent_every=2))


# --------------------------------------------------------------------- #
# bit-identity: descent on == descent off == monolithic solve
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("algo", ["greedy", "rg", "rnp"])
@pytest.mark.parametrize("backend", ["jnp", "blocked"])
def test_descent_parity_across_backends_and_algos(algo, backend):
    g = rgg2d(500, avg_deg=8, seed=3)
    cfg0, cfgd = _cfgs(backend)
    pg = part.partition_graph(g, 4, window_cap=12)
    m_mono, _ = S.solve(pg, algo, cfg0)
    m_off, _ = S.solve_staged(g, 4, algo, cfg0, window_cap=12)
    m_on, st = S.solve_staged(g, 4, algo, cfgd, window_cap=12,
                              ladder=TINY_LADDER)
    assert np.array_equal(m_mono, m_off)
    assert np.array_equal(m_mono, m_on)
    assert st["descents"] >= 1, "tiny ladder should trigger a descent"
    assert g.is_independent_set(m_on)


@pytest.mark.parametrize("gen,kw", [
    (gnm, dict(m=1600)), (rgg2d, dict(avg_deg=8)),
])
def test_descent_parity_seeded_families(gen, kw):
    for seed in (0, 4):
        g = gen(400, seed=seed, **kw)
        cfg0, cfgd = _cfgs()
        m_off, _ = S.solve_staged(g, 2, "rnp", cfg0, window_cap=12)
        m_on, st = S.solve_staged(g, 2, "rnp", cfgd, window_cap=12,
                                  ladder=TINY_LADDER)
        assert np.array_equal(m_off, m_on), f"{gen.__name__} seed={seed}"


def test_descent_parity_sync_mode_and_multiple_descents():
    g = rgg2d(500, avg_deg=8, seed=7)
    cfg0, cfgd = _cfgs(mode="sync")
    m_off, _ = S.solve_staged(g, 2, "rnp", cfg0)
    m_on, st = S.solve_staged(g, 2, "rnp", cfgd, ladder=TINY_LADDER)
    assert np.array_equal(m_off, m_on)
    assert st["descents"] >= 2, st["path"]
    # the path walks strictly downward in L
    Ls = [e["L"] for e in st["path"]]
    assert all(a > b for a, b in zip(Ls, Ls[1:])), Ls


def test_descent_property_random_instances():
    hyp = pytest.importorskip("hypothesis")  # optional dep
    from hypothesis import given, settings, strategies as hst

    @settings(max_examples=10, deadline=None)
    @given(hst.integers(0, 10_000), hst.sampled_from([1, 2]),
           hst.sampled_from(["gnm", "rgg"]))
    def prop(seed, p, fam):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(20, 120))
        g = gnm(n, 3 * n, seed=seed) if fam == "gnm" \
            else rgg2d(n, avg_deg=6, seed=seed)
        cfg0, cfgd = _cfgs()
        m_off, _ = S.solve_staged(g, p, "rg", cfg0, window_cap=8)
        m_on, _ = S.solve_staged(g, p, "rg", cfgd, window_cap=8,
                                 ladder=TINY_LADDER)
        assert np.array_equal(m_off, m_on)

    prop()


# --------------------------------------------------------------------- #
# residual weight gate: int64 → int32 must be checked, never wrap
# --------------------------------------------------------------------- #


def test_residual_weights_near_int32_max():
    w = np.array([0, 1, VAL.I32_MAX], dtype=np.int64)
    out = VAL.residual_weights(w)
    assert out.dtype == np.int32 and int(out[2]) == VAL.I32_MAX

    with pytest.raises(VAL.InvalidInstance) as ei:
        VAL.residual_weights(np.array([VAL.I32_MAX + 1], dtype=np.int64))
    assert ei.value.reason == VAL.REASON_BAD_WEIGHT

    with pytest.raises(VAL.InvalidInstance):
        VAL.residual_weights(np.array([-1], dtype=np.int64))


def test_compact_partition_rejects_overflowing_residual():
    """The old solve_compact silently wrapped int64 folded weights via
    .astype(np.int32); compact_partition must reject them instead."""
    g = gnm(24, 60, seed=1)
    pg = part.partition_graph(g, 2, window_cap=8)
    status = np.zeros(pg.p * pg.V, dtype=np.int8)  # everything alive
    w = np.zeros(pg.p * pg.V, dtype=np.int64)
    w[: pg.V] = VAL.I32_MAX  # at the limit: fine
    pg2 = part.compact_partition(pg, status, w)
    assert int(np.asarray(pg2.w0).max()) == VAL.I32_MAX

    w[0] = VAL.I32_MAX + 1  # one past: must raise, not wrap negative
    alive0 = bool(pg.is_local[0, 0] or pg.is_ghost[0, 0])
    assert alive0  # slot 0 is a real vertex in this layout
    with pytest.raises(VAL.InvalidInstance) as ei:
        part.compact_partition(pg, status, w)
    assert ei.value.reason == VAL.REASON_BAD_WEIGHT


# --------------------------------------------------------------------- #
# descent-tagged plan-cache counters
# --------------------------------------------------------------------- #


def test_plan_cache_descent_counters():
    cache = E.PlanCache(max_entries=8)
    builds = []
    cache.get_or_build("k1", lambda: builds.append(1) or "p1",
                       tag="descent")
    cache.get_or_build("k1", lambda: builds.append(1) or "p1",
                       tag="descent")
    cache.get_or_build("k2", lambda: builds.append(1) or "p2")
    s = cache.stats
    assert (s.descent_hits, s.descent_misses) == (1, 1)
    # descent counters are a tagged subset of the plain totals
    assert s.misses == 2 and s.hits == 1
    assert len(builds) == 2


def test_descent_plans_hit_cache_on_repeat_solve():
    g = rgg2d(400, avg_deg=8, seed=5)
    cfg = D.DisReduConfig(mode="async", heavy_k=6, backend="blocked",
                          descent=True, descent_every=2)
    cache = E.PlanCache(max_entries=32)
    m1, st1 = S.solve_staged(g, 2, "rnp", cfg, window_cap=12,
                             ladder=TINY_LADDER, plan_cache=cache)
    assert st1["descents"] >= 1
    miss1 = cache.stats.descent_misses
    m2, _ = S.solve_staged(g, 2, "rnp", cfg, window_cap=12,
                           ladder=TINY_LADDER, plan_cache=cache)
    assert np.array_equal(m1, m2)
    s = cache.stats
    assert s.descent_misses == miss1, "repeat solve rebuilt descent plans"
    assert s.descent_hits >= st1["descents"]


# --------------------------------------------------------------------- #
# checkpoint + resume across a descent boundary
# --------------------------------------------------------------------- #


def test_resume_across_descent_boundary(tmp_path):
    from repro.distributed.checkpoint import CheckpointManager

    g = rgg2d(400, avg_deg=8, seed=9)
    cfg = D.DisReduConfig(mode="async", heavy_k=6, descent=True,
                          descent_every=2)
    m_ref, st_ref = S.solve_staged(g, 2, "rnp", cfg, window_cap=12,
                                   ladder=TINY_LADDER)
    assert st_ref["descents"] >= 1

    class Die(RuntimeError):
        pass

    ck = CheckpointManager(str(tmp_path / "ck"), async_write=False)

    def killer(descents, cell_name):
        raise Die(f"killed after descent {descents} -> {cell_name}")

    with pytest.raises(Die):
        S.solve_staged(g, 2, "rnp", cfg, window_cap=12,
                       ladder=TINY_LADDER, ckpt=ck, on_descent=killer)
    assert ck.latest_step() == 1  # saved before the fault fired

    m_res, st_res = S.solve_staged(g, 2, "rnp", cfg, window_cap=12,
                                   ladder=TINY_LADDER, ckpt=ck,
                                   resume=True)
    assert np.array_equal(m_ref, m_res)
    assert st_res["descents"] == st_ref["descents"]
    assert [e["cell"] for e in st_res["path"]] == \
        [e["cell"] for e in st_ref["path"]]


# --------------------------------------------------------------------- #
# serving integration
# --------------------------------------------------------------------- #


def test_serve_descent_auto_matches_off():
    gs = [gnm(200, 700, seed=s) for s in range(3)]  # serve_s bucket
    off = SV.MWISService(SV.ServeConfig(algo="rg", verify="full"))
    on = SV.MWISService(SV.ServeConfig(algo="rg", verify="full",
                                       descent="auto", descent_min_L=256))
    r_off = off.solve_batch(gs)
    r_on = on.solve_batch(gs)
    for a, b in zip(r_off, r_on):
        assert a.ok and b.ok
        assert np.array_equal(a.members, b.members)
        assert a.weight == b.weight
    assert on.stats["descent_solves"] == len(gs)


def test_serve_oversize_admitted_through_descent_cells():
    big = SV.serve_cells()[-1].L + 200
    g = gnm(big, 2 * big, seed=2)
    off = SV.MWISService(SV.ServeConfig(algo="rg"))
    r = off.solve_one(g)
    assert not r.ok and r.reason == VAL.REASON_OVERSIZE

    on = SV.MWISService(SV.ServeConfig(algo="rg", descent="auto"))
    r = on.solve_one(g)
    assert r.ok, (r.reason, r.error)
    assert VAL.verify_result(g, r.members, r.weight).ok
    st = on.stats
    assert st["oversize_admitted"] == 1 and st["descent_solves"] == 1


def test_serve_descent_rejects_beyond_descent_cells():
    huge_n = max(c.L for c in SV.descent_entry_cells()) + 1
    g = SV.Graph(indptr=np.zeros(huge_n + 1, np.int64),
                 indices=np.zeros(0, np.int32),
                 weights=np.ones(huge_n, np.int32))
    svc = SV.MWISService(SV.ServeConfig(descent="auto"))
    r = svc.solve_one(g)
    assert not r.ok and r.reason == VAL.REASON_OVERSIZE
