"""Distributed reduction + solvers on the union path (exact SPMD semantics)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import distributed as D
from repro.core import partition as part
from repro.core import sequential as seq
from repro.core import solvers as S
from repro.core.bitset_mwis import mwis_exact
from repro.graphs import generators as gen
from tests.helpers import MED_PAD, SMALL_PAD, residual_exact_weight


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 100_000), st.sampled_from([2, 4]),
       st.sampled_from(["sync", "async"]))
def test_distributed_reduce_preserves_alpha(seed, p, mode):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 13))
    g = gen.random_graph(n, float(rng.uniform(0.1, 0.7)), seed=seed)
    best, _ = mwis_exact(g)
    pg = part.partition_graph(g, p, window_cap=8, common_cap=4,
                              pad_to=SMALL_PAD)
    cfg = D.DisReduConfig(heavy_k=6, mode=mode, max_rounds=200)
    state, prob, rounds = D.disredu(pg, cfg)
    wgt, indep = residual_exact_weight(g, pg, state, prob)
    assert indep and wgt == best
    assert rounds < 200


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 100_000), st.sampled_from([1, 3, 4]))
def test_greedy_equals_sequential_oracle(seed, p):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 40))
    g = gen.random_graph(n, 0.15, seed=seed)
    if seed % 2:  # force weight ties
        g = type(g)(indptr=g.indptr, indices=g.indices,
                    weights=(g.weights % 3 + 1).astype(np.int32))
    want, _ = seq.solve_greedy(g)
    pg = part.partition_graph(g, p, window_cap=8, pad_to=MED_PAD)
    members, _ = S.solve(pg, "greedy")
    assert g.is_independent_set(members)
    assert g.set_weight(members) == want


@pytest.mark.parametrize("algo", ["rg", "rnp"])
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_solvers_complete_and_sound(algo, mode):
    for seed in range(3):
        g = gen.rhg_like(250, avg_deg=6, seed=seed)
        pg = part.partition_graph(g, 4, window_cap=12)
        members, state = S.solve(
            pg, algo, D.DisReduConfig(heavy_k=6, mode=mode)
        )
        assert g.is_independent_set(members)
        assert g.set_weight(members) > 0


def test_rnp_quality_close_to_sequential():
    """Paper Table 7.1 analogue: distributed RnP stays within a few % of
    the sequential reduce-and-peel baseline."""
    ratios = []
    for seed in range(4):
        g = gen.rhg_like(300, avg_deg=6, seed=seed)
        w_seq, _ = seq.solve_reduce_and_peel(g)
        pg = part.partition_graph(g, 4, window_cap=12)
        members, _ = S.solve(
            pg, "rnp", D.DisReduConfig(heavy_k=6, mode="async")
        )
        ratios.append(g.set_weight(members) / max(w_seq, 1))
    assert np.mean(ratios) > 0.93, ratios


def test_reduction_impact_worsens_mildly_with_p():
    """Paper Fig 7.1: kernel size grows with p but stays bounded."""
    g = gen.rgg2d(2000, avg_deg=8, seed=0)
    sizes = {}
    for p in (1, 4, 8):
        pg = part.partition_graph(g, p, window_cap=12)
        cfg = D.DisReduConfig(heavy_k=8, mode="sync")
        state, prob, _ = D.disredu(pg, cfg)
        nv, ne = D.kernel_stats(pg, state)
        sizes[p] = nv / g.n
    assert sizes[4] >= sizes[1] - 1e-9
    assert sizes[8] <= sizes[1] + 0.30  # stays bounded (paper: ~+10% median)


def test_async_matches_sync_fixpoint_quality():
    g = gen.rgg2d(800, avg_deg=8, seed=1)
    res = {}
    for mode in ("sync", "async"):
        pg = part.partition_graph(g, 4, window_cap=12)
        state, prob, _ = D.disredu(pg, D.DisReduConfig(mode=mode))
        res[mode] = D.kernel_stats(pg, state)
    # both reach a fixpoint of the same rule family; sizes should be close
    nv_s, nv_a = res["sync"][0], res["async"][0]
    assert abs(nv_s - nv_a) <= 0.1 * max(nv_s, nv_a, 1)
