"""Shared test utilities."""

from __future__ import annotations

import numpy as np

from repro.core import rules as R
from repro.core.bitset_mwis import mwis_exact
from repro.core.graph import Graph, from_edge_list

# uniform shape buckets → one jit compilation per (p, mode) across all cases
SMALL_PAD = dict(L=8, G=14, E=220, B=8, S=8)
MED_PAD = dict(L=40, G=60, E=700, B=40, S=40)


def residual_exact_weight(g: Graph, pg, state, prob) -> tuple[int, bool]:
    """Brute-force the reduced kernel, reconstruct, return (weight, indep)."""
    import jax.numpy as jnp

    from repro.core import distributed as D

    status = np.asarray(state.status)
    w = np.asarray(state.w)
    is_local = np.asarray(prob.is_local)
    gids = np.asarray(prob.aux.gid)
    alive = [i for i in range(status.shape[0]) if status[i] == 0 and is_local[i]]
    alive_g = sorted(set(int(gids[i]) for i in alive))
    remap = {gg: k for k, gg in enumerate(alive_g)}
    edges = set()
    row = np.asarray(prob.aux.row)
    col = np.asarray(prob.aux.col)
    for e in range(row.shape[0]):
        r, c = int(row[e]), int(col[e])
        if r >= gids.shape[0] or gids[r] < 0 or gids[c] < 0:
            continue
        if status[r] == 0 and status[c] == 0 and is_local[r]:
            a, b = int(gids[r]), int(gids[c])
            if a in remap and b in remap:
                edges.add((min(remap[a], remap[b]), max(remap[a], remap[b])))
    wts = np.zeros(len(alive_g), dtype=np.int64)
    for i in alive:
        wts[remap[int(gids[i])]] = w[i]
    sub = from_edge_list(len(alive_g), list(edges), wts)
    _, msub = mwis_exact(sub)
    status2 = status.copy()
    for i in range(status.shape[0]):
        gg = int(gids[i])
        if status[i] == 0 and gg in remap:
            status2[i] = R.INCLUDED if msub[remap[gg]] else R.EXCLUDED
    st2 = state._replace(status=jnp.asarray(status2))
    members = D.members_global(pg, st2, prob.aux)
    return g.set_weight(members), g.is_independent_set(members)
