"""Admission gate + verified outputs: canonicalize/reject semantics, the
post-solve checker, and adversarial instances through the hardened service."""

import numpy as np
import pytest

from repro.core import engine as E
from repro.core import serve as SV
from repro.core import validate as V
from repro.core.graph import Graph, from_edge_list
from repro.graphs.generators import gnm

# --------------------------------------------------------------------- #
# canonicalize: repairs
# --------------------------------------------------------------------- #


def _csr(n, pairs, w=None):
    """Build a Graph from explicit DIRECTED (src, dst) pairs — unlike
    from_edge_list this does NOT symmetrize/dedup, so tests can hand the
    validator genuinely malformed edge lists."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    order = np.lexsort((pairs[:, 1], pairs[:, 0]))
    pairs = pairs[order]
    counts = np.bincount(pairs[:, 0], minlength=n)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    weights = (np.arange(n, dtype=np.int32) + 1) if w is None \
        else np.asarray(w)
    return Graph(indptr=indptr, indices=pairs[:, 1].astype(np.int32),
                 weights=weights)


def test_canonical_graph_is_returned_by_identity():
    g = from_edge_list(4, [(0, 1), (1, 2), (2, 3)],
                       np.array([5, 1, 5, 1], np.int32))
    fixed, rep = V.canonicalize(g)
    assert rep.ok and rep.repairs == ()
    assert fixed is g          # identity preserved → topology cache hits


def test_self_loops_dropped():
    g = _csr(3, [(0, 0), (0, 1), (1, 0), (2, 2)])
    fixed, rep = V.canonicalize(g)
    assert rep.ok and V.REPAIR_SELF_LOOPS in rep.repairs
    src = fixed.edge_sources()
    assert not np.any(src == fixed.indices)
    # the 0–1 edge survives
    assert fixed.num_directed_edges == 2


def test_duplicate_and_asymmetric_edges_repaired():
    g = _csr(3, [(0, 1), (0, 1), (1, 0), (1, 2)])   # dup 0→1, missing 2→1
    fixed, rep = V.canonicalize(g)
    assert rep.ok
    assert V.REPAIR_DUP_EDGES in rep.repairs
    assert V.REPAIR_SYMMETRIZED in rep.repairs
    und = set(map(tuple, np.stack(
        [fixed.edge_sources(), fixed.indices], 1).tolist()))
    assert und == {(0, 1), (1, 0), (1, 2), (2, 1)}


def test_unsorted_rows_resorted():
    # row 0 lists neighbors out of order; edge *set* is already canonical
    indptr = np.array([0, 2, 3, 4])
    indices = np.array([2, 1, 0, 0], np.int32)
    g = Graph(indptr=indptr, indices=indices,
              weights=np.array([1, 2, 3], np.int32))
    fixed, rep = V.canonicalize(g)
    assert rep.ok and V.REPAIR_RESORTED in rep.repairs
    assert np.array_equal(fixed.indices[:2], [1, 2])


def test_integral_float_weights_cast():
    g = Graph(indptr=np.array([0, 1, 2]), indices=np.array([1, 0], np.int32),
              weights=np.array([3.0, 4.0]))
    fixed, rep = V.canonicalize(g)
    assert rep.ok and V.REPAIR_WEIGHT_CAST in rep.repairs
    assert fixed.weights.dtype == np.int32


# --------------------------------------------------------------------- #
# canonicalize: rejects (stable reason codes)
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("weights,why", [
    (np.array([np.nan, 1.0]), "nan"),
    (np.array([np.inf, 1.0]), "inf"),
    (np.array([1.5, 2.0]), "non-integral"),
    (np.array([-1, 2], np.int64), "negative"),
    (np.array([2**40, 2], np.int64), "overflow"),
])
def test_bad_weights_rejected(weights, why):
    g = Graph(indptr=np.array([0, 1, 2]), indices=np.array([1, 0], np.int32),
              weights=weights)
    fixed, rep = V.canonicalize(g)
    assert fixed is None and not rep.ok, why
    assert rep.reason == V.REASON_BAD_WEIGHT


def test_out_of_range_index_rejected():
    g = Graph(indptr=np.array([0, 1, 2]), indices=np.array([5, 0], np.int32),
              weights=np.array([1, 2], np.int32))
    _, rep = V.canonicalize(g)
    assert not rep.ok and rep.reason == V.REASON_BAD_INDEX


@pytest.mark.parametrize("indptr", [
    np.array([0, 2]),            # wrong length for n=2
    np.array([1, 1, 2]),         # indptr[0] != 0
    np.array([0, 2, 1]),         # non-monotone
    np.array([0, 1, 5]),         # indptr[-1] != len(indices)
])
def test_broken_csr_rejected(indptr):
    g = Graph(indptr=indptr, indices=np.array([1, 0], np.int32),
              weights=np.array([1, 2], np.int32))
    _, rep = V.canonicalize(g)
    assert not rep.ok and rep.reason == V.REASON_BAD_CSR


def test_validate_instance_raises_with_reason():
    g = Graph(indptr=np.array([0, 0]), indices=np.array([], np.int32),
              weights=np.array([-3], np.int64))
    with pytest.raises(V.InvalidInstance) as ei:
        V.validate_instance(g)
    assert ei.value.reason == V.REASON_BAD_WEIGHT


# --------------------------------------------------------------------- #
# verify_result
# --------------------------------------------------------------------- #


def test_verify_result_accepts_independent_set():
    g = from_edge_list(4, [(0, 1), (1, 2), (2, 3)],
                       np.array([5, 1, 5, 1], np.int32))
    m = np.array([True, False, True, False])
    rep = V.verify_result(g, m, 10)
    assert rep.ok and rep.weight == 10


def test_verify_result_flags_conflict_and_weight_mismatch():
    g = from_edge_list(3, [(0, 1)], np.array([2, 3, 4], np.int32))
    bad = np.array([True, True, False])
    rep = V.verify_result(g, bad)
    assert not rep.ok and "endpoint" in rep.detail
    good = np.array([False, True, True])
    rep2 = V.verify_result(g, good, weight=99)
    assert not rep2.ok and rep2.reason == V.REASON_VERIFY_FAILED
    assert rep2.weight == 7


def test_verify_result_rejects_wrong_shape():
    g = from_edge_list(3, [(0, 1)], np.array([2, 3, 4], np.int32))
    assert not V.verify_result(g, np.array([True, False])).ok
    assert not V.verify_result(g, np.array([1, 0, 1])).ok   # not bool


# --------------------------------------------------------------------- #
# adversarial instances through the hardened service
# --------------------------------------------------------------------- #

BACKENDS = [
    b for b in ("jnp", "blocked", "pallas") if b in E.BACKENDS
]


@pytest.fixture(scope="module")
def services():
    return {
        b: SV.MWISService(SV.ServeConfig(backend=b, verify="full"))
        for b in BACKENDS
    }


@pytest.mark.parametrize("backend", BACKENDS)
def test_adversarial_instances_reject_not_crash(services, backend):
    svc = services[backend]
    nan_g = Graph(indptr=np.array([0, 1, 2]),
                  indices=np.array([1, 0], np.int32),
                  weights=np.array([np.nan, 1.0]))
    neg_g = from_edge_list(2, [(0, 1)], np.array([-5, 1], np.int64))
    loop_g = _csr(3, [(0, 0), (0, 1), (1, 0)],
                  np.array([7, 3, 9], np.int32))
    empty_g = from_edge_list(0, [], np.zeros(0, np.int32))
    iso_g = from_edge_list(3, [], np.array([1, 2, 3], np.int32))
    results = svc.solve_batch([nan_g, neg_g, loop_g, empty_g, iso_g])
    assert not results[0].ok and results[0].reason == V.REASON_BAD_WEIGHT
    assert not results[1].ok and results[1].reason == V.REASON_BAD_WEIGHT
    # repaired + solved + verified
    assert results[2].ok and results[2].weight == 9 + 7
    assert results[3].ok and results[3].weight == 0
    assert results[3].members.shape == (0,)
    assert results[4].ok and results[4].weight == 6   # all isolated picked
    for r in (r for r in results if r.ok and r.members.size):
        assert r.members.dtype == np.bool_


def test_oversize_reject_names_the_distributed_path():
    svc = SV.MWISService(SV.ServeConfig())
    big = svc.cells[-1].L + 1
    g = from_edge_list(big, [], np.ones(big, np.int32))
    r = svc.solve_one(g)
    assert not r.ok and r.reason == V.REASON_OVERSIZE
    assert "solvers.solve" in r.error
    assert svc.stats["rejected"] == 1


def test_verify_full_audits_every_request(services):
    svc = services["jnp"]
    before = svc.counters["verify_checked"]
    gs = [gnm(20, 40, seed=s) for s in range(4)]
    rs = svc.solve_batch(gs)
    assert all(r.ok for r in rs)
    assert svc.counters["verify_checked"] - before >= 4
    assert svc.counters["verify_failures"] == 0


# --------------------------------------------------------------------- #
# hypothesis property: random adversarial CSR soup → reject or verified
# --------------------------------------------------------------------- #


def test_property_adversarial_soup():
    pytest.importorskip("hypothesis")  # optional dep: skip, don't error
    from hypothesis import given, settings, strategies as st

    svc = SV.MWISService(SV.ServeConfig(backend="jnp", verify="full"))

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 100_000), st.booleans(), st.booleans(),
           st.booleans(), st.booleans())
    def prop(seed, add_loops, add_dups, drop_reverse, poison_weights):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(0, 16))
        m = int(rng.integers(0, max(2 * n, 1)))
        pairs = []
        for _ in range(m):
            u, v = int(rng.integers(0, max(n, 1))), \
                int(rng.integers(0, max(n, 1)))
            if u == v and not add_loops:
                continue
            pairs.append((u, v))
            if not drop_reverse and u != v:
                pairs.append((v, u))
            if add_dups:
                pairs.append((u, v))
        w = rng.integers(1, 100, size=n).astype(np.int32)
        if poison_weights and n:
            w = w.astype(np.float64)
            w[int(rng.integers(0, n))] = [np.nan, np.inf, -1.0, 0.5][
                int(rng.integers(0, 4))]
        g = _csr(n, pairs, w) if pairs else Graph(
            indptr=np.zeros(n + 1, np.int64),
            indices=np.zeros(0, np.int32), weights=w)
        r = svc.solve_one(g)    # must never raise
        if r.ok:
            fixed, rep = V.canonicalize(g)
            assert rep.ok
            assert V.verify_result(fixed, r.members, r.weight).ok
        else:
            assert r.reason in (
                V.REASON_BAD_WEIGHT, V.REASON_BAD_CSR, V.REASON_BAD_INDEX,
            )
            assert r.error and not np.any(r.members)

    prop()
