"""Deterministic fault-injection drills (the `chaos` lane).

Proves the robustness claims the hardened runtime makes:

  * bounded-staleness safety — delayed/dropped halo boards reach the SAME
    fixpoint as the fault-free run (stale ghost weights stay valid upper
    bounds, Lemma 4.2);
  * restartability — a run killed mid-sweep and restored from its
    `RedState` checkpoint finishes bit-identical to an uninterrupted run;
  * detection — an injected monotonicity breach (weight bumped up) is
    flagged by the harness's invariant checker;
  * serving isolation — a poisoned batch yields per-request errors while
    healthy instances solve bit-identically; a failing backend falls down
    the `pallas → blocked → jnp` chain instead of failing the batch.
"""

import numpy as np
import pytest

from repro.core import distributed as D
from repro.core import engine as E
from repro.core import partition as part
from repro.core import serve as SV
from repro.core import validate as VAL
from repro.core.graph import Graph
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.fault import (
    FaultPlan, InjectedFault, run_union_reduction,
)
from repro.graphs.generators import gnm, random_graph
from tests.helpers import SMALL_PAD

pytestmark = pytest.mark.chaos


def _problem(seed, p=2):
    g = random_graph(12, 0.3, seed=seed)
    pg = part.partition_graph(g, p, window_cap=8, common_cap=4,
                              pad_to=SMALL_PAD)
    cfg = D.DisReduConfig(heavy_k=6, mode="sync", max_rounds=200)
    return D.build_union_problem(pg, cfg.backend), cfg


def _final(state):
    return np.asarray(state.w), np.asarray(state.status)


# --------------------------------------------------------------------- #
# bounded-staleness: delays and drops do not change the fixpoint
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_same_fixpoint_under_injected_delays(seed):
    prob, cfg = _problem(seed)
    base, _, rep0 = run_union_reduction(prob, cfg)
    assert rep0["fixpoint"] and not rep0["violations"]
    for fseed in range(3):
        plan = FaultPlan.random_delay(fseed, p=2)
        st, _, rep = run_union_reduction(prob, cfg, faults=plan)
        assert rep["fixpoint"], f"no fixpoint under {plan}"
        assert not rep["violations"]
        bw, bs = _final(base)
        fw, fs = _final(st)
        assert np.array_equal(bw, fw) and np.array_equal(bs, fs), \
            f"fixpoint diverged under {plan}"


def test_same_fixpoint_under_dropped_boards():
    prob, cfg = _problem(seed=5)
    base, _, _ = run_union_reduction(prob, cfg)
    plan = FaultPlan(drop_pe=1, drop_rounds=2, drop_from=0)
    st, _, rep = run_union_reduction(prob, cfg, faults=plan)
    assert rep["fixpoint"] and not rep["violations"]
    assert any(e[0] == "dropped" for e in rep["events"])
    assert np.array_equal(*map(np.asarray, (base.w, st.w)))
    assert np.array_equal(*map(np.asarray, (base.status, st.status)))


# --------------------------------------------------------------------- #
# kill + restore: bit-identical restart from a RedState checkpoint
# --------------------------------------------------------------------- #


def test_restart_from_checkpoint_is_bit_identical(tmp_path):
    from repro.core import rules as R

    prob, cfg = _problem(seed=7)
    base, _, _ = run_union_reduction(prob, cfg)

    ck = CheckpointManager(str(tmp_path / "ck"))
    with pytest.raises(InjectedFault):
        run_union_reduction(prob, cfg, faults=FaultPlan(kill_round=1),
                            ckpt=ck, save_every=1)
    step = ck.latest_step()
    assert step is not None

    template = R.init_state(prob.w0, prob.is_local, prob.is_ghost)
    restored = ck.restore(template)
    st, _, rep = run_union_reduction(prob, cfg, state=restored,
                                     start_round=step + 1)
    assert rep["fixpoint"]
    assert np.array_equal(np.asarray(base.w), np.asarray(st.w))
    assert np.array_equal(np.asarray(base.status), np.asarray(st.status))
    assert np.array_equal(np.asarray(base.offset), np.asarray(st.offset))


def test_restart_across_descent_boundary_is_bit_identical(tmp_path):
    """Kill the staged solver right after a shape descent commits its
    checkpoint; resume must replay the compaction chain from the manifest
    and finish bit-identical to the uninterrupted run."""
    from repro.core import solvers as S
    from repro.graphs.generators import rgg2d

    ladder = tuple(
        S.LadderCell(name=f"t{L}", L=L, E=E, G=max(L // 2, 4),
                     B=max(L // 4, 4), S=max(L // 4, 4))
        for L, E in ((8, 128), (16, 256), (32, 512), (64, 1024),
                     (128, 2048))
    )
    g = rgg2d(400, avg_deg=8, seed=13)
    cfg = D.DisReduConfig(heavy_k=6, mode="sync", descent=True,
                          descent_every=2)
    m_ref, st_ref = S.solve_staged(g, 2, "rnp", cfg, window_cap=12,
                                   ladder=ladder)
    assert st_ref["descents"] >= 1

    ck = CheckpointManager(str(tmp_path / "ck"), async_write=False)

    def kill(descents, cell_name):
        raise InjectedFault(f"killed after descent {descents}")

    with pytest.raises(InjectedFault):
        S.solve_staged(g, 2, "rnp", cfg, window_cap=12, ladder=ladder,
                       ckpt=ck, on_descent=kill)
    assert ck.latest_step() is not None
    assert ck.manifest()["extra"]["kind"] == "solve_staged"

    m_res, st_res = S.solve_staged(g, 2, "rnp", cfg, window_cap=12,
                                   ladder=ladder, ckpt=ck, resume=True)
    assert np.array_equal(m_ref, m_res)
    assert st_res["path"] == st_ref["path"]


# --------------------------------------------------------------------- #
# detection: an injected monotonicity breach is flagged
# --------------------------------------------------------------------- #


def test_weight_corruption_is_detected():
    prob, cfg = _problem(seed=9)
    plan = FaultPlan(seed=1, corrupt_pe=0, corrupt_round=0)
    _, _, rep = run_union_reduction(prob, cfg, faults=plan)
    assert any(e[0] == "corrupted" for e in rep["events"])
    assert any(v[0] == "weight_increased" for v in rep["violations"])


def test_fault_free_run_matches_disredu_reference():
    g = random_graph(12, 0.3, seed=11)
    pg = part.partition_graph(g, 2, window_cap=8, common_cap=4,
                              pad_to=SMALL_PAD)
    cfg = D.DisReduConfig(heavy_k=6, mode="sync", max_rounds=200)
    prob = D.build_union_problem(pg, cfg.backend)
    harness_state, _, rep = run_union_reduction(prob, cfg)
    ref_state, _, _ = D.disredu(pg, cfg)
    assert rep["fixpoint"]
    assert np.array_equal(np.asarray(harness_state.w),
                          np.asarray(ref_state.w))
    assert np.array_equal(np.asarray(harness_state.status),
                          np.asarray(ref_state.status))


# --------------------------------------------------------------------- #
# serving isolation: poisoned batches and failing backends
# --------------------------------------------------------------------- #


def test_poisoned_batch_isolates_per_request():
    svc = SV.MWISService(SV.ServeConfig(backend="jnp"))
    good = [gnm(20, 40, seed=s) for s in range(3)]
    nan_g = Graph(indptr=np.array([0, 1, 2]),
                  indices=np.array([1, 0], np.int32),
                  weights=np.array([np.nan, 1.0]))
    big = svc.cells[-1].L + 1
    oversize = Graph(indptr=np.zeros(big + 1, np.int64),
                     indices=np.zeros(0, np.int32),
                     weights=np.ones(big, np.int32))
    batch = [good[0], nan_g, good[1], oversize, good[2]]
    results = svc.solve_batch(batch)

    assert not results[1].ok and results[1].reason == VAL.REASON_BAD_WEIGHT
    assert not results[3].ok and results[3].reason == VAL.REASON_OVERSIZE
    assert results[3].members.shape == (big,) and not results[3].members.any()

    # healthy requests solve bit-identically to an unpoisoned service
    fresh = SV.MWISService(SV.ServeConfig(backend="jnp"))
    want = fresh.solve_batch(good)
    for got, ref in zip((results[0], results[2], results[4]), want):
        assert got.ok and ref.ok
        assert np.array_equal(got.members, ref.members)
        assert got.weight == ref.weight
    assert svc.stats["rejected"] == 2 and svc.stats["requests"] == 5


def test_backend_fallback_chain_recovers():
    start = "pallas" if "pallas" in E.BACKENDS else "blocked"
    svc = SV.MWISService(SV.ServeConfig(backend=start, verify="full"))
    real = SV.MWISService._execute_chunk

    def flaky(self, cell, topos, backend):
        if backend != "jnp":
            raise RuntimeError(f"injected {backend} failure")
        return real(self, cell, topos, backend)

    svc._execute_chunk = flaky.__get__(svc)
    g = gnm(20, 40, seed=0)
    r = svc.solve_one(g)
    assert r.ok and VAL.verify_result(g, r.members, r.weight).ok
    st = svc.stats
    assert st["backend"] == start and st["backend_active"] == "jnp"
    assert st["fallbacks"] >= 1 and st["solve_errors"] == 0

    # ...and the demotion is sticky: next request goes straight to jnp
    before = st["fallbacks"]
    r2 = svc.solve_one(gnm(20, 40, seed=1))
    assert r2.ok and svc.stats["fallbacks"] == before


def test_exhausted_fallback_chain_degrades_to_error():
    svc = SV.MWISService(SV.ServeConfig(backend="jnp"))

    def broken(self, cell, topos, backend):
        raise RuntimeError("injected total failure")

    svc._execute_chunk = broken.__get__(svc)
    r = svc.solve_one(gnm(20, 40, seed=0))
    assert not r.ok and r.reason == VAL.REASON_BACKEND_FAILED
    assert svc.stats["solve_errors"] == 1
