"""Parity harness: the aggregate-engine path == the seed path, bit for bit.

The engine refactor deleted the seed's per-rule aggregate recomputation
branches from ``rules.py``; this harness proves nothing changed by running
the full DisRedu{S,A} pipeline against the frozen seed implementation
(``tests/seed_oracle.py``) on the generator-graph matrix and asserting the
final ``status`` / ``w`` / ``offset`` arrays are **bit-identical**:

  * engine schedule "cheap"       == seed per-rule path (fused_sweeps=False),
  * engine schedule "cheap-fused" == seed fused path   (fused_sweeps=True),
  * all aggregate backends (jnp / blocked / pallas-interpret) agree exactly
    (int32 payloads — addition is associative, so layout cannot matter).

The shard_map-path parity (same assertion across the production execution
path) lives in ``tests/test_shardmap.py`` (multi-device subprocess).
"""

import numpy as np
import pytest

from repro.core import distributed as D
from repro.core import partition as part
from repro.graphs import generators as gen
from tests import seed_oracle as O
from tests.helpers import SMALL_PAD


def _small_graphs():
    """Brute-force-scale graphs sharing one compiled program (SMALL_PAD)."""
    out = []
    for seed in range(4):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(4, 13))
        out.append((f"rand{seed}", gen.random_graph(n, 0.4, seed=seed)))
    return out


def _generator_graphs():
    """One instance per paper generator family (laptop scale)."""
    return [
        ("rgg", gen.rgg2d(240, avg_deg=7, seed=1)),
        ("rhg", gen.rhg_like(240, avg_deg=6, seed=2)),
        ("gnm", gen.gnm(200, 600, seed=3)),
    ]


def _assert_bit_identical(state_engine, state_seed, label):
    np.testing.assert_array_equal(
        np.asarray(state_engine.status), np.asarray(state_seed.status),
        err_msg=f"{label}: status diverged",
    )
    np.testing.assert_array_equal(
        np.asarray(state_engine.w), np.asarray(state_seed.w),
        err_msg=f"{label}: weights diverged",
    )
    assert int(state_engine.offset) == int(state_seed.offset), \
        f"{label}: offset diverged"


def _run_matrix(schedule, fused, graphs, pad=None, ps=(1, 2)):
    for name, g in graphs:
        for p in ps:
            for mode in ("sync", "async"):
                pg = part.partition_graph(
                    g, p, window_cap=8, common_cap=4, pad_to=pad
                )
                se, _, _ = D.disredu(pg, D.DisReduConfig(
                    heavy_k=6, mode=mode, schedule=schedule
                ))
                so, _ = O.disredu_union_oracle(
                    pg, heavy_k=6, mode=mode, fused=fused
                )
                _assert_bit_identical(
                    se, so, f"{name}/p{p}/{mode}/{schedule}"
                )


def test_engine_cheap_matches_seed_per_rule_path_small():
    _run_matrix("cheap", False, _small_graphs(), pad=SMALL_PAD)


def test_engine_cheap_fused_matches_seed_fused_path_small():
    _run_matrix("cheap-fused", True, _small_graphs(), pad=SMALL_PAD)


@pytest.mark.slow
@pytest.mark.parametrize("schedule,fused", [
    ("cheap", False), ("cheap-fused", True),
])
def test_engine_matches_seed_on_generator_matrix(schedule, fused):
    _run_matrix(schedule, fused, _generator_graphs())


@pytest.mark.parametrize("backend", ["blocked", "pallas"])
def test_backends_bit_identical_to_jnp(backend):
    """Blocked-ELL backends (ref + pallas interpret) == jnp, bit for bit."""
    for name, g in _small_graphs():
        pg = part.partition_graph(
            g, 2, window_cap=8, common_cap=4, pad_to=SMALL_PAD
        )
        for schedule in ("cheap", "cheap-fused"):
            sj, _, _ = D.disredu(pg, D.DisReduConfig(
                heavy_k=6, schedule=schedule, backend="jnp"
            ))
            sb, _, _ = D.disredu(pg, D.DisReduConfig(
                heavy_k=6, schedule=schedule, backend=backend
            ))
            _assert_bit_identical(sb, sj, f"{name}/{schedule}/{backend}")


@pytest.mark.slow
def test_blocked_backend_bit_identical_on_generator_graph():
    g = gen.rgg2d(240, avg_deg=7, seed=4)
    pg = part.partition_graph(g, 4, window_cap=8)
    sj, _, _ = D.disredu(pg, D.DisReduConfig(
        heavy_k=6, mode="async", schedule="cheap-fused", backend="jnp"
    ))
    sb, _, _ = D.disredu(pg, D.DisReduConfig(
        heavy_k=6, mode="async", schedule="cheap-fused", backend="blocked"
    ))
    _assert_bit_identical(sb, sj, "rgg/p4/async/blocked")
