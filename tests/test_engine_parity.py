"""Parity harness: the aggregate-engine path == the seed path, bit for bit.

The engine refactor deleted the seed's per-rule aggregate recomputation
branches from ``rules.py``; this harness proves nothing changed by running
the full DisRedu{S,A} pipeline against the frozen seed implementation
(``tests/seed_oracle.py``) on the generator-graph matrix and asserting the
final ``status`` / ``w`` / ``offset`` arrays are **bit-identical**:

  * engine schedule "cheap"       == seed per-rule path (fused_sweeps=False),
  * engine schedule "cheap-fused" == seed fused path   (fused_sweeps=True),
  * all aggregate backends (jnp / blocked / pallas-interpret) agree exactly
    (int32 payloads — addition is associative, so layout cannot matter),
  * the engine-computed window bits (``ctx.act_bits`` / ``ctx.clique`` —
    fused edge-pass OR payloads on the blocked backends, the vectorized
    [V, D] form on jnp) == the seed's D-unrolled window gather loop, for
    arbitrary status/weight states,
  * the solver paths (greedy / RnP) are unchanged by the backend routing,
    and distributed greedy still equals the ``sequential.solve_greedy``
    priority-greedy oracle exactly.

The shard_map-path parity (same assertion across the production execution
path) lives in ``tests/test_shardmap.py`` (multi-device subprocess).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed as D
from repro.core import engine as E
from repro.core import partition as part
from repro.core import rules as R
from repro.core import sequential as seq
from repro.core import solvers as S
from repro.graphs import generators as gen
from tests import seed_oracle as O
from tests.helpers import SMALL_PAD


def _small_graphs():
    """Brute-force-scale graphs sharing one compiled program (SMALL_PAD)."""
    out = []
    for seed in range(4):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(4, 13))
        out.append((f"rand{seed}", gen.random_graph(n, 0.4, seed=seed)))
    return out


def _generator_graphs():
    """One instance per paper generator family (laptop scale)."""
    return [
        ("rgg", gen.rgg2d(240, avg_deg=7, seed=1)),
        ("rhg", gen.rhg_like(240, avg_deg=6, seed=2)),
        ("gnm", gen.gnm(200, 600, seed=3)),
    ]


def _assert_bit_identical(state_engine, state_seed, label):
    np.testing.assert_array_equal(
        np.asarray(state_engine.status), np.asarray(state_seed.status),
        err_msg=f"{label}: status diverged",
    )
    np.testing.assert_array_equal(
        np.asarray(state_engine.w), np.asarray(state_seed.w),
        err_msg=f"{label}: weights diverged",
    )
    assert int(state_engine.offset) == int(state_seed.offset), \
        f"{label}: offset diverged"


def _run_matrix(schedule, fused, graphs, pad=None, ps=(1, 2)):
    for name, g in graphs:
        for p in ps:
            for mode in ("sync", "async"):
                pg = part.partition_graph(
                    g, p, window_cap=8, common_cap=4, pad_to=pad
                )
                se, _, _ = D.disredu(pg, D.DisReduConfig(
                    heavy_k=6, mode=mode, schedule=schedule
                ))
                so, _ = O.disredu_union_oracle(
                    pg, heavy_k=6, mode=mode, fused=fused
                )
                _assert_bit_identical(
                    se, so, f"{name}/p{p}/{mode}/{schedule}"
                )


def test_engine_cheap_matches_seed_per_rule_path_small():
    _run_matrix("cheap", False, _small_graphs(), pad=SMALL_PAD)


def test_engine_cheap_fused_matches_seed_fused_path_small():
    _run_matrix("cheap-fused", True, _small_graphs(), pad=SMALL_PAD)


@pytest.mark.slow
@pytest.mark.parametrize("schedule,fused", [
    ("cheap", False), ("cheap-fused", True),
])
def test_engine_matches_seed_on_generator_matrix(schedule, fused):
    _run_matrix(schedule, fused, _generator_graphs())


@pytest.mark.parametrize("backend", ["blocked", "pallas"])
def test_backends_bit_identical_to_jnp(backend):
    """Blocked-ELL backends (ref + pallas interpret) == jnp, bit for bit."""
    for name, g in _small_graphs():
        pg = part.partition_graph(
            g, 2, window_cap=8, common_cap=4, pad_to=SMALL_PAD
        )
        for schedule in ("cheap", "cheap-fused"):
            sj, _, _ = D.disredu(pg, D.DisReduConfig(
                heavy_k=6, schedule=schedule, backend="jnp"
            ))
            sb, _, _ = D.disredu(pg, D.DisReduConfig(
                heavy_k=6, schedule=schedule, backend=backend
            ))
            _assert_bit_identical(sb, sj, f"{name}/{schedule}/{backend}")


@pytest.mark.slow
def test_blocked_backend_bit_identical_on_generator_graph():
    g = gen.rgg2d(240, avg_deg=7, seed=4)
    pg = part.partition_graph(g, 4, window_cap=8)
    sj, _, _ = D.disredu(pg, D.DisReduConfig(
        heavy_k=6, mode="async", schedule="cheap-fused", backend="jnp"
    ))
    sb, _, _ = D.disredu(pg, D.DisReduConfig(
        heavy_k=6, mode="async", schedule="cheap-fused", backend="blocked"
    ))
    _assert_bit_identical(sb, sj, "rgg/p4/async/blocked")


# --------------------------------------------------------------------- #
# window-bit parity: engine ctx == the frozen D-unrolled seed loop
# --------------------------------------------------------------------- #
def _assert_window_bits_match_seed(pg, label, n_states=4):
    """For arbitrary status/weight states, every backend's act_bits/clique
    must equal the seed loop bit for bit."""
    req = frozenset({"act_bits", "clique", "S", "deg", "M", "only"})
    rng = np.random.default_rng(0)
    probs = {b: D.build_union_problem(pg, b) for b in E.BACKENDS}
    for k in range(n_states):
        state = R.init_state(
            probs["jnp"].w0, probs["jnp"].is_local, probs["jnp"].is_ghost
        )
        if k:  # perturb: arbitrary statuses + shrunk weights
            st = rng.integers(0, 4, size=probs["jnp"].w0.shape[0])
            state = state._replace(
                status=jnp.asarray(st.astype(np.int8)),
                w=jnp.asarray(
                    rng.integers(0, 50, size=st.shape).astype(np.int32)
                ),
            )
        want_bits = np.asarray(O._window_active_bits(state, probs["jnp"].aux))
        want_clq = np.asarray(
            O._is_clique(state, probs["jnp"].aux, jnp.asarray(want_bits))
        )
        for backend, prob in probs.items():
            ctx = E.compute_ctx(
                state, prob.aux, req, backend=backend, plan=prob.plan
            )
            np.testing.assert_array_equal(
                np.asarray(ctx.act_bits), want_bits,
                err_msg=f"{label}/{backend}/state{k}: act_bits diverged",
            )
            np.testing.assert_array_equal(
                np.asarray(ctx.clique), want_clq,
                err_msg=f"{label}/{backend}/state{k}: clique diverged",
            )


def test_window_bits_match_seed_loop_small():
    for name, g in _small_graphs():
        for p in (1, 2):
            pg = part.partition_graph(
                g, p, window_cap=8, common_cap=4, pad_to=SMALL_PAD
            )
            _assert_window_bits_match_seed(pg, f"{name}/p{p}")


@pytest.mark.slow
def test_window_bits_match_seed_loop_on_generator_matrix():
    for name, g in _generator_graphs():
        pg = part.partition_graph(g, 4, window_cap=12)
        _assert_window_bits_match_seed(pg, f"{name}/p4", n_states=2)


# --------------------------------------------------------------------- #
# solver-path parity: backend routing must not change solver results
# --------------------------------------------------------------------- #
def test_solver_paths_identical_across_backends_and_greedy_oracle():
    for name, g in (
        [("rgg300", gen.rgg2d(300, avg_deg=7, seed=5))]
        + [gr for gr in _small_graphs()[:2]]
    ):
        for algo in ("greedy", "rg", "rnp"):
            members = {}
            for backend in E.BACKENDS:
                pg = part.partition_graph(g, 2, window_cap=8, common_cap=4)
                m, _ = S.solve(pg, algo, D.DisReduConfig(
                    heavy_k=6, mode="async", backend=backend
                ))
                assert g.is_independent_set(m), f"{name}/{algo}/{backend}"
                members[backend] = m
            for backend in ("blocked", "pallas"):
                np.testing.assert_array_equal(
                    members[backend], members["jnp"],
                    err_msg=f"{name}/{algo}/{backend}: members diverged",
                )
            if algo == "greedy":
                _, m_seq = seq.solve_greedy(g)
                np.testing.assert_array_equal(
                    members["jnp"], m_seq,
                    err_msg=f"{name}: distributed greedy != sequential "
                            "priority greedy",
                )


def test_row_arrays_sorted_for_aggregate_sorted_flag():
    """engine.aggregate passes indices_are_sorted=True for Aux rows — the
    partition (and its union concatenation) must keep rows sorted."""
    for name, g in _small_graphs()[:2] + [("rgg", gen.rgg2d(200, avg_deg=6,
                                                            seed=6))]:
        for p in (1, 3):
            pg = part.partition_graph(g, p, window_cap=8)
            for i in range(p):
                assert (np.diff(pg.row[i]) >= 0).all(), f"{name}/pe{i}"
            prob = D.build_union_problem(pg)
            assert (np.diff(np.asarray(prob.aux.row)) >= 0).all(), \
                f"{name}/union"
