"""shard_map production path == union simulation path (subprocess with
multiple host devices; exercises lax collectives incl. the a2a exchange)."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json, sys
    import numpy as np
    import jax
    from repro.core import distributed as D, partition as part, solvers as S
    from repro.graphs import generators as gen
    from repro.launch.mesh import make_host_mesh

    g = gen.rgg2d(400, avg_deg=7, seed=5)
    pg = part.partition_graph(g, 4, window_cap=8)
    out = {}
    for exchange in ("allgather", "a2a"):
        cfg = D.DisReduConfig(heavy_k=6, mode="sync", exchange=exchange)
        mesh = make_host_mesh(4)
        run, keys = S.solver_shard_map_fn(pg, cfg, mesh, "rnp", axis="pe")
        import jax.numpy as jnp
        arrays = {k: jnp.asarray(v) for k, v in pg.device_arrays().items()}
        w, status, members, offset, logn = run(arrays)
        members = np.asarray(members)
        gids = pg.gid
        glob = np.zeros(g.n, dtype=bool)
        for i in range(4):
            sel = members[i] & pg.is_local[i]
            glob[gids[i][sel]] = True
        assert g.is_independent_set(glob), exchange
        out[exchange] = int(g.weights[glob].sum())
    # union-path result for comparison
    members_u, _ = S.solve(pg, "rnp", D.DisReduConfig(heavy_k=6, mode="sync"))
    out["union"] = int(g.weights[members_u].sum())
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_shard_map_matches_union_and_a2a_matches_allgather(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src"
    )
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, timeout=1800,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    # all three execution paths produce identical solution weights
    assert out["allgather"] == out["a2a"] == out["union"], out


PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax.numpy as jnp
    from repro.core import distributed as D, partition as part
    from repro.graphs import generators as gen
    from repro.launch.mesh import make_host_mesh

    g = gen.rgg2d(400, avg_deg=7, seed=5)
    pg = part.partition_graph(g, 4, window_cap=8)
    V = pg.V
    for schedule, backend in (("cheap", "jnp"), ("cheap-fused", "blocked")):
        for mode in ("sync", "async"):
            cfg = D.DisReduConfig(heavy_k=6, mode=mode, schedule=schedule,
                                  backend=backend)
            mesh = make_host_mesh(4)
            run, keys = D.disredu_shard_map_fn(pg, cfg, mesh, axis="pe")
            w, status, _, _, _, _, offset, _ = run()
            su, _, _ = D.disredu(pg, cfg)   # union path, same config
            tag = f"{schedule}/{backend}/{mode}"
            assert np.array_equal(
                np.asarray(status), np.asarray(su.status).reshape(4, V)
            ), f"status diverged: {tag}"
            assert np.array_equal(
                np.asarray(w), np.asarray(su.w).reshape(4, V)
            ), f"weights diverged: {tag}"
            assert int(np.asarray(offset).sum()) == int(su.offset), \\
                f"offset diverged: {tag}"
    print("PARITY OK")
""")


@pytest.mark.slow
def test_shard_map_reduction_bit_identical_to_union():
    """Engine path parity across execution paths: DisRedu{S,A} under
    shard_map produces bit-identical per-PE status/w (and total offset) to
    the union simulation, for both refresh granularities and backends."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src"
    )
    r = subprocess.run(
        [sys.executable, "-c", PARITY_SCRIPT], capture_output=True,
        text=True, env=env, timeout=1800,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PARITY OK" in r.stdout
