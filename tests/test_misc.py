"""Smaller units: HLO parser, samplers, generators, compression, optimizer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo
from repro.distributed import compression as comp
from repro.graphs import generators as gen
from repro.graphs.sampler import build_triplets, sample_fanout
from repro.train import optimizer as opt


HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ag = f32[256,128]{1,0} all-gather(%p0), dimensions={0}
  %ar = bf16[1024]{0} all-reduce(%x), to_apply=%sum
  %rs = f32[64,128]{1,0} reduce-scatter(%ag), dimensions={0}
  %a2a = s32[8,32]{1,0} all-to-all(%idx), dimensions={0}
  %cp = f32[16,128]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  ROOT %t = tuple(%ar)
}
"""


def test_collective_bytes_parser():
    out = hlo.collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 256 * 128 * 4
    assert out["all-reduce"] == 1024 * 2
    assert out["reduce-scatter"] == 64 * 128 * 4
    assert out["all-to-all"] == 8 * 32 * 4
    assert out["collective-permute"] == 16 * 128 * 4


def test_collective_parser_on_real_lowering():
    import os
    if jax.device_count() < 1:
        pytest.skip("no devices")
    # single-device module has no collectives
    low = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
    )
    assert hlo.collective_bytes(low.compile().as_text()) == {}


def test_fanout_sampler_invariants():
    g = gen.rgg2d(500, avg_deg=8, seed=0)
    rng = np.random.default_rng(0)
    seeds = np.arange(16)
    sub = sample_fanout(g, seeds, (5, 3), rng=rng,
                        pad_nodes=600, pad_edges=900)
    assert sub.n_seeds == 16
    assert (sub.node_ids[:sub.n_valid] >= 0).all()
    # sampled edges connect real neighbors
    for e in range(sub.row.shape[0]):
        r, c = int(sub.row[e]), int(sub.col[e])
        if r >= sub.n_sub:
            continue
        u, v = int(sub.node_ids[r]), int(sub.node_ids[c])
        assert g.has_edge(u, v) or g.has_edge(v, u)
    # fanout bound: each target takes at most f neighbors per layer
    deg = {}
    for e in range(sub.row.shape[0]):
        if int(sub.row[e]) < sub.n_sub:
            deg[int(sub.col[e])] = deg.get(int(sub.col[e]), 0) + 1
    assert max(deg.values()) <= 5


def test_triplets_share_pivot():
    g = gen.rgg2d(80, avg_deg=6, seed=1)
    src = g.edge_sources().astype(np.int32)
    dst = g.indices.astype(np.int32)
    tri = build_triplets(src, dst, g.n, budget=200)
    E = src.shape[0]
    for t in range(tri.shape[0]):
        e_in, e_out = int(tri[t, 0]), int(tri[t, 1])
        if e_in >= E:
            continue
        # in-edge (k -> j) feeds out-edge (j -> i); k != i
        assert dst[e_in] == src[e_out]
        assert src[e_in] != dst[e_out]


def test_generator_families_shape():
    for name, make in gen.FAMILIES.items():
        g = make(500, seed=0)
        g.validate()
        assert g.n == 500
        assert g.m > 100, name


def test_int8_compression_error_feedback_converges():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    ef = comp.ef_init(g)
    # accumulated dequantized grads approach accumulated true grads
    acc_true = np.zeros(64)
    acc_deq = np.zeros(64)
    for step in range(30):
        q, s, ef = comp.compress_int8_ef(g, ef)
        acc_true += np.asarray(g["w"])
        acc_deq += np.asarray(comp.dequantize_int8(q["w"], s["w"]))
    err0 = np.abs(np.asarray(g["w"]) - comp.dequantize_int8(
        *comp.compress_int8_ef(g, comp.ef_init(g))[:2]
    )["w"] if False else 0)
    rel = np.abs(acc_deq - acc_true).max() / np.abs(acc_true).max()
    assert rel < 0.05  # EF keeps long-run bias tiny


def test_adamw_and_adafactor_reduce_quadratic_loss():
    def loss(p):
        return jnp.sum((p["w"] - 3.0) ** 2)

    for name in ("adamw", "adafactor"):
        init, update, cfg = opt.OPTIMIZERS[name]
        if name == "adamw":
            cfg = opt.AdamWConfig(lr=0.1)
        else:
            cfg = opt.AdafactorConfig(lr=0.3)
        params = {"w": jnp.zeros((4, 4))}
        state = init(params)
        l0 = float(loss(params))
        for _ in range(60):
            grads = jax.grad(loss)(params)
            params, state = update(grads, state, params, cfg)
        assert float(loss(params)) < 0.05 * l0, name


def test_hierarchical_psum_matches_flat(tmp_path):
    """Sum over (pod, data) via hierarchy == plain psum (subprocess-free:
    checked algebraically on the union of shards)."""
    # algebraic check of the decomposition on host values
    rng = np.random.default_rng(0)
    shards = rng.normal(size=(2, 4, 8))  # pod x data x payload
    flat = shards.sum((0, 1))
    # reduce-scatter (split payload across data) -> pod sum -> all-gather
    chunks = shards.reshape(2, 4, 4, 2)  # data-many chunks of the payload
    rs = chunks.sum(1)                   # intra-pod reduce-scatter result
    ps = rs.sum(0)                       # cross-pod psum per chunk
    ag = ps.reshape(8)                   # all-gather
    np.testing.assert_allclose(ag, flat, rtol=1e-12)
