"""Frozen seed-PR reduction rules — the parity oracle for the aggregate engine.

This file is a verbatim copy of ``src/repro/core/rules.py`` as of the seed
commit (plus a seed-faithful union-path driver at the bottom), kept so the
engine refactor can be proven *bit-identical* to the original per-rule and
fused sweep paths long after those branches were deleted from the live code.
Do NOT "fix" or modernise this module: its value is that it never changes.

  * ``sweep_cheap``       — seed per-rule path (every rule recomputes its
    aggregates fresh; the seed's ``fused_sweeps=False`` default),
  * ``sweep_cheap_fused`` — seed fused path (aggregates snapshotted once per
    sweep; the seed's ``fused_sweeps=True``),
  * ``disredu_union_oracle`` — the seed DisRedu{S,A} round loop on the union
    layout, importing only modules this PR does not touch (exchange).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.ops import segment_max, segment_sum

UNDECIDED, INCLUDED, EXCLUDED, FOLDED = 0, 1, 2, 3
LOG_FOLD1, LOG_WT = 1, 2

I32_MIN = jnp.iinfo(jnp.int32).min


class Aux(NamedTuple):
    """Static (per-PE) graph structure; never modified by reductions."""

    row: jax.Array            # [E] i32 source local idx (pad = nil)
    col: jax.Array            # [E] i32 target local idx (pad = nil)
    gid: jax.Array            # [V] i32 global id (nil/pad = -1)
    is_local: jax.Array       # [V] bool
    is_iface: jax.Array       # [V] bool
    owner_rank: jax.Array     # [V] i32 owning PE (tie-breaking, Lemma 4.5)
    window: jax.Array         # [V, D] i32 capped neighbor lists (pad = nil)
    win_complete: jax.Array   # [V] bool
    win_adj_bits: jax.Array   # [V, D] i32 static pairwise adjacency bits
    edge_common: jax.Array    # [E, Dc] i32 capped common neighborhoods


class RedState(NamedTuple):
    """Mutable reduction state (one PE)."""

    w: jax.Array        # [V] i32 current weights
    status: jax.Array   # [V] i8
    log_kind: jax.Array  # [LOG] i8   (fold log for reconstruction)
    log_v: jax.Array    # [LOG] i32
    log_u: jax.Array    # [LOG] i32
    log_n: jax.Array    # [] i32
    offset: jax.Array   # [] i32  (weight reclaimed by folds; reporting)
    changed: jax.Array  # [] bool (any rule fired in the current sweep)


def init_state(w0: jax.Array, is_local: jax.Array, is_ghost: jax.Array) -> RedState:
    V = w0.shape[0]
    L = int(is_local.shape[0])
    status = jnp.where(is_local | is_ghost, UNDECIDED, EXCLUDED).astype(jnp.int8)
    log_cap = V + 1  # each fold retires one vertex forever => never overflows
    return RedState(
        w=w0.astype(jnp.int32),
        status=status,
        log_kind=jnp.zeros(log_cap, jnp.int8),
        log_v=jnp.zeros(log_cap, jnp.int32),
        log_u=jnp.zeros(log_cap, jnp.int32),
        log_n=jnp.zeros((), jnp.int32),
        offset=jnp.zeros((), jnp.int32),
        changed=jnp.zeros((), bool),
    )


# --------------------------------------------------------------------- #
# shared masked aggregates
# --------------------------------------------------------------------- #
def _active(state: RedState) -> jax.Array:
    return state.status == UNDECIDED


def _edge_active(aux: Aux, active: jax.Array) -> jax.Array:
    return active[aux.row] & active[aux.col]


def _aw(state: RedState, active: jax.Array) -> jax.Array:
    return jnp.where(active, state.w, 0)


def _nbr_sum(aux: Aux, eact: jax.Array, vals: jax.Array, V: int) -> jax.Array:
    contrib = jnp.where(eact, vals[aux.col], 0)
    return segment_sum(contrib, aux.row, num_segments=V)


def _nbr_max(aux: Aux, eact: jax.Array, vals: jax.Array, V: int) -> jax.Array:
    contrib = jnp.where(eact, vals[aux.col], I32_MIN)
    return jnp.maximum(segment_max(contrib, aux.row, num_segments=V), I32_MIN)


def _act_deg(aux: Aux, eact: jax.Array, V: int) -> jax.Array:
    return segment_sum(eact.astype(jnp.int32), aux.row, num_segments=V)


def _accept_independent(
    aux: Aux, eact: jax.Array, cand: jax.Array, V: int
) -> jax.Array:
    """Filter include candidates to an independent set (gid priority)."""
    nbr_cand_gid = jnp.where(eact & cand[aux.col], aux.gid[aux.col], -1)
    m = segment_max(nbr_cand_gid, aux.row, num_segments=V)
    m = jnp.maximum(m, -1)
    return cand & (aux.gid > m)


def _apply_include(
    state: RedState, aux: Aux, eact: jax.Array, accept: jax.Array
) -> RedState:
    status = jnp.where(accept, jnp.int8(INCLUDED), state.status)
    hit = segment_max(
        (accept[aux.row] & eact).astype(jnp.int32), aux.col,
        num_segments=state.w.shape[0],
    ) > 0
    status = jnp.where(hit & (status == UNDECIDED), jnp.int8(EXCLUDED), status)
    return state._replace(status=status, changed=state.changed | accept.any())


def _log_append(
    state: RedState, mask: jax.Array, kind: int, v_idx: jax.Array,
    u_idx: jax.Array
) -> RedState:
    cap = state.log_kind.shape[0]
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    pos = jnp.where(mask, state.log_n + rank, cap - 1)
    # cap-1 slot is a scratch sentinel; log_n never reaches it (see init_state)
    log_kind = state.log_kind.at[pos].set(jnp.where(mask, jnp.int8(kind), 0))
    log_v = state.log_v.at[pos].set(jnp.where(mask, v_idx, 0))
    log_u = state.log_u.at[pos].set(jnp.where(mask, u_idx, 0))
    n = state.log_n + mask.sum(dtype=jnp.int32)
    return state._replace(log_kind=log_kind, log_v=log_v, log_u=log_u, log_n=n)


class SweepCtx(NamedTuple):
    """Aggregates snapshotted once per sweep (fused-sweep mode).

    Soundness of staleness (EXPERIMENTS.md §Perf H3): adjacency is static
    and weights/activity only decrease, so snapshot aggregates are upper
    bounds of their fresh values — every rule test is monotone in the safe
    direction.  Rule *applications* and certificate activity always use
    fresh status (recomputed eact), so cross-family conflicts inside one
    sweep cannot arise."""

    S: jax.Array         # [V] neighborhood weight sums
    deg: jax.Array       # [V] active degrees
    M: jax.Array         # [V] max neighbor weight
    only: jax.Array      # [V] the unique active neighbor (deg-1 vertices)
    act_bits: jax.Array  # [V] window active bits
    clique: jax.Array    # [V] active window forms a clique


def compute_ctx(state: RedState, aux: Aux) -> SweepCtx:
    V = state.w.shape[0]
    active = _active(state)
    eact = _edge_active(aux, active)
    aw = _aw(state, active)
    S = _nbr_sum(aux, eact, aw, V)
    deg = _act_deg(aux, eact, V)
    M = _nbr_max(aux, eact, state.w, V)
    only = jnp.maximum(
        segment_max(jnp.where(eact, aux.col, -1), aux.row, num_segments=V), 0
    )
    act_bits = _window_active_bits(state, aux)
    clique = _is_clique(state, aux, act_bits)
    return SweepCtx(S=S, deg=deg, M=M, only=only, act_bits=act_bits,
                    clique=clique)


# --------------------------------------------------------------------- #
# rule: degree zero / one  (Meta rule + Remark 4.8, fold form of Gu et al.)
# --------------------------------------------------------------------- #
def rule_degree_one(state: RedState, aux: Aux, ctx: "SweepCtx" = None) -> RedState:
    V = state.w.shape[0]
    active = _active(state)
    eact = _edge_active(aux, active)
    if ctx is None:
        deg = _act_deg(aux, eact, V)
        only = segment_max(
            jnp.where(eact, aux.col, -1), aux.row, num_segments=V
        )
        only = jnp.maximum(only, 0)
    else:
        deg, only = ctx.deg, ctx.only
    w_u = state.w[only]

    # (a) isolated vertices
    acc0 = aux.is_local & active & (deg == 0)
    state = _apply_include(state, aux, eact, acc0)

    # (b) degree-one include: w(v) >= w_i(u)  — upper bound is enough
    #     (ghost case: propose per Remark 4.6)
    active = _active(state)
    eact = _edge_active(aux, active)
    cand = aux.is_local & active & (deg == 1) & (state.w >= w_u)
    acc1 = _accept_independent(aux, eact, cand, V)
    state = _apply_include(state, aux, eact, acc1)

    # (c) degree-one fold: w(v) < w(u), u local:
    #       w(u) -= w(v);  v FOLDED;  v ∈ I  iff  u ∉ I.
    active = _active(state)
    cand = aux.is_local & active & (deg == 1) & (state.w < w_u)
    cand &= aux.is_local[only] & active[only]
    # one fold per target u per sweep: keep the max-gid candidate
    tgt = jnp.where(cand, only, V - 1)
    best = jnp.full(V, -1, jnp.int32).at[tgt].max(jnp.where(cand, aux.gid, -1))
    acc = cand & (aux.gid == best[only])
    w = state.w.at[jnp.where(acc, only, V - 1)].add(
        jnp.where(acc, -state.w, 0)
    )
    w = w.at[V - 1].set(0)
    status = jnp.where(acc, jnp.int8(FOLDED), state.status)
    offset = state.offset + jnp.where(acc, state.w, 0).sum(dtype=jnp.int32)
    state = state._replace(
        w=w, status=status, offset=offset, changed=state.changed | acc.any()
    )
    idx = jnp.arange(V, dtype=jnp.int32)
    return _log_append(state, acc, LOG_FOLD1, idx, only.astype(jnp.int32))


# --------------------------------------------------------------------- #
# rule: Dist. Neighborhood Removal (Reduction 4.3)
# --------------------------------------------------------------------- #
def rule_neighborhood_removal(state: RedState, aux: Aux,
                              ctx: "SweepCtx" = None) -> RedState:
    V = state.w.shape[0]
    active = _active(state)
    eact = _edge_active(aux, active)
    s = ctx.S if ctx is not None else _nbr_sum(
        aux, eact, _aw(state, active), V
    )
    cand = aux.is_local & active & (state.w >= s)
    acc = _accept_independent(aux, eact, cand, V)
    return _apply_include(state, aux, eact, acc)


# --------------------------------------------------------------------- #
# clique machinery shared by simplicial rules (static adjacency bits)
# --------------------------------------------------------------------- #
def _window_active_bits(state: RedState, aux: Aux) -> jax.Array:
    """[V] i32 — bit i set iff window[v, i] is an UNDECIDED vertex."""
    D = aux.window.shape[1]
    active = _active(state)
    bits = jnp.zeros(state.w.shape[0], jnp.int32)
    for i in range(D):
        ent = aux.window[:, i]
        bits |= (active[ent] & (aux.gid[ent] >= 0)).astype(jnp.int32) << i
    return bits


def _is_clique(state: RedState, aux: Aux, act_bits: jax.Array) -> jax.Array:
    """[V] bool — do the *active* window entries form a clique?

    Exact when win_complete (window = full static neighbor list); the caller
    must gate on win_complete.  Ghost pairs have no stored edge, so ≥2 active
    ghost neighbors naturally fail — matching "a clique in G_i contains at
    most one ghost".
    """
    D = aux.window.shape[1]
    ok = jnp.ones(state.w.shape[0], bool)
    for i in range(D):
        need = act_bits & ~jnp.int32(1 << i)
        have = aux.win_adj_bits[:, i]
        active_i = (act_bits >> i) & 1
        bad = (active_i == 1) & ((need & ~have) != 0)
        ok &= ~bad
    return ok


# --------------------------------------------------------------------- #
# rule: Distributed Simplicial Vertex (Reduction 4.4)
# --------------------------------------------------------------------- #
def rule_simplicial(state: RedState, aux: Aux,
                    ctx: "SweepCtx" = None) -> RedState:
    V = state.w.shape[0]
    active = _active(state)
    eact = _edge_active(aux, active)
    if ctx is None:
        act_bits = _window_active_bits(state, aux)
        clique = _is_clique(state, aux, act_bits)
        m = _nbr_max(aux, eact, state.w, V)
    else:
        act_bits, clique, m = ctx.act_bits, ctx.clique, ctx.M
    cand = (
        aux.is_local & active & aux.win_complete & clique & (state.w >= m)
    )
    acc = _accept_independent(aux, eact, cand, V)
    return _apply_include(state, aux, eact, acc)


# --------------------------------------------------------------------- #
# rule: Dist. Simplicial Weight Transfer (Reduction 4.5)
# --------------------------------------------------------------------- #
def rule_weight_transfer(state: RedState, aux: Aux,
                         ctx: "SweepCtx" = None) -> RedState:
    V = state.w.shape[0]
    D = aux.window.shape[1]
    active = _active(state)
    eact = _edge_active(aux, active)
    if ctx is None:
        act_bits = _window_active_bits(state, aux)
        clique = _is_clique(state, aux, act_bits)
        m = _nbr_max(aux, eact, state.w, V)
        deg = _act_deg(aux, eact, V)
    else:
        act_bits, clique, m, deg = ctx.act_bits, ctx.clique, ctx.M, ctx.deg

    # v must be max-weight among the simplicial vertices of N(v).  A neighbor
    # whose simpliciality we cannot decide (incomplete window) blocks v.
    simpl_known = aux.win_complete & clique
    nbr_blocks = eact & (state.w[aux.col] > state.w[aux.row]) & (
        simpl_known[aux.col] | ~aux.win_complete[aux.col]
    )
    blocked = segment_max(
        nbr_blocks.astype(jnp.int32), aux.row, num_segments=V
    ) > 0

    cand = (
        aux.is_local & active & ~aux.is_iface & simpl_known
        & (state.w < m) & ~blocked & (deg >= 1)
    )
    # unique within two hops (gid priority) => disjoint closed neighborhoods
    m1 = segment_max(
        jnp.where(eact & cand[aux.col], aux.gid[aux.col], -1), aux.row,
        num_segments=V,
    )
    m1 = jnp.maximum(m1, -1)
    m2 = segment_max(jnp.where(eact, m1[aux.col], -1), aux.row, num_segments=V)
    m2 = jnp.maximum(m2, -1)
    acc = cand & (aux.gid > m1) & (aux.gid >= m2)

    # apply the fold: remove X = {u in N[v]: w(u) <= w(v)}, transfer weight.
    # entry activity here must be FRESH (application, not test)
    fresh_bits = act_bits if ctx is None else _window_active_bits(state, aux)
    wv = state.w
    tgt = aux.window  # [V, D]
    ent_active = ((fresh_bits[:, None] >> jnp.arange(D)[None, :]) & 1) == 1
    accb = acc[:, None]
    excl_upd = accb & ent_active & (state.w[tgt] <= wv[:, None])
    dec_upd = accb & ent_active & (state.w[tgt] > wv[:, None])
    nil_slot = V - 1
    status = state.status.at[jnp.where(excl_upd, tgt, nil_slot)].set(
        jnp.where(excl_upd, jnp.int8(EXCLUDED), jnp.int8(EXCLUDED))
    )
    # (scatter writes EXCLUDED either way; nil slot is EXCLUDED by invariant)
    status = jnp.where(acc, jnp.int8(FOLDED), status)
    w = state.w.at[jnp.where(dec_upd, tgt, nil_slot)].add(
        jnp.where(dec_upd, -wv[:, None], 0)
    )
    w = w.at[nil_slot].set(0)
    offset = state.offset + jnp.where(acc, wv, 0).sum(dtype=jnp.int32)
    state = state._replace(
        w=w, status=status, offset=offset, changed=state.changed | acc.any()
    )
    idx = jnp.arange(V, dtype=jnp.int32)
    return _log_append(state, acc, LOG_WT, idx, idx)


# --------------------------------------------------------------------- #
# rule: Distributed Basic Single-Edge (Reduction 4.6)
# --------------------------------------------------------------------- #
def rule_basic_single_edge(state: RedState, aux: Aux,
                           ctx: "SweepCtx" = None) -> RedState:
    V = state.w.shape[0]
    active = _active(state)
    eact = _edge_active(aux, active)
    aw = _aw(state, active)
    s = ctx.S if ctx is not None else _nbr_sum(aux, eact, aw, V)
    # capped common-neighborhood weight (lower bound => conservative)
    c = jnp.where(
        active[aux.edge_common], aw[aux.edge_common], 0
    ).sum(axis=1)
    val = s[aux.row] - c  # >= true ω(N(u) \ N(v)) which contains v itself
    test = (
        eact
        & aux.is_local[aux.row] & aux.is_local[aux.col]
        & (val <= state.w[aux.row])
        & (aux.gid[aux.row] > aux.gid[aux.col])  # ascending certificate chain
    )
    excl = segment_max(test.astype(jnp.int32), aux.col, num_segments=V) > 0
    status = jnp.where(
        excl & active & aux.is_local, jnp.int8(EXCLUDED), state.status
    )
    fired = (excl & active & aux.is_local).any()
    return state._replace(status=status, changed=state.changed | fired)


# --------------------------------------------------------------------- #
# rule: Dist. Extended Single-Edge (Reduction 4.7)
# --------------------------------------------------------------------- #
def rule_extended_single_edge(state: RedState, aux: Aux,
                              ctx: "SweepCtx" = None) -> RedState:
    V = state.w.shape[0]
    active = _active(state)
    eact = _edge_active(aux, active)
    aw = _aw(state, active)
    s = ctx.S if ctx is not None else _nbr_sum(aux, eact, aw, V)
    # edge e = (v=row, u=col):  w(v) >= S(v) - aw(u)  => exclude common nbrs
    test = (
        eact
        & aux.is_local[aux.row] & aux.is_local[aux.col]
        & (s[aux.row] - aw[aux.col] <= state.w[aux.row])
    )
    min_gid = jnp.minimum(aux.gid[aux.row], aux.gid[aux.col])
    tgt = aux.edge_common  # [E, Dc]
    upd = (
        test[:, None]
        & active[tgt] & aux.is_local[tgt]
        & (aux.gid[tgt] < min_gid[:, None])
        & (aux.gid[tgt] >= 0)
    )
    nil_slot = V - 1
    status = state.status.at[jnp.where(upd, tgt, nil_slot)].set(jnp.int8(EXCLUDED))
    fired = upd.any()
    return state._replace(status=status, changed=state.changed | fired)


# --------------------------------------------------------------------- #
# rule: Distributed Heavy Vertex (Reduction 4.2) — exact sub-MWIS
# --------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("heavy_k",))
def _alpha_neighborhood(
    w: jax.Array, status: jax.Array, aux: Aux, heavy_k: int
) -> jax.Array:
    """[V] i32 — exact α(G_i[N_i(v)]) for active windows with ≤K active
    entries; 2^K subset enumeration against static adjacency bitmasks (the
    KaMIS-wB&R stand-in, vectorised for the VPU/MXU)."""
    V, D = aux.window.shape
    K = heavy_k
    active = status == UNDECIDED
    ent_ok = active[aux.window] & (aux.gid[aux.window] >= 0)  # [V, D]
    # stable-sort entries: active first, keep the first K
    order = jnp.argsort(~ent_ok, axis=1, stable=True)[:, :K]  # [V, K]
    ent = jnp.take_along_axis(aux.window, order, axis=1)      # [V, K]
    ent_act = jnp.take_along_axis(ent_ok, order, axis=1)      # [V, K]
    wk = jnp.where(ent_act, w[ent], 0).astype(jnp.int32)      # [V, K]
    # permuted adjacency bits: bit j of row i = adjacency(order_i, order_j)
    bits_full = jnp.take_along_axis(aux.win_adj_bits, order, axis=1)  # [V, K]
    adj = jnp.zeros((V, K), jnp.int32)
    for j in range(K):
        oj = order[:, j]
        bit_j = (bits_full >> oj[:, None]) & 1  # [V, K] adjacency to entry j
        adj |= bit_j << j
    subsets = jnp.arange(1 << K, dtype=jnp.int32)               # [T]
    sel = ((subsets[:, None] >> jnp.arange(K)[None, :]) & 1)     # [T, K]
    totals = wk @ sel.T.astype(jnp.int32)                        # [V, T]
    conflict = jnp.zeros(totals.shape, bool)
    for i in range(K):
        in_sub = sel[:, i] == 1                                  # [T]
        hits = (subsets[None, :] & adj[:, i : i + 1]) != 0       # [V, T]
        conflict |= in_sub[None, :] & hits
    alpha = jnp.where(conflict, -1, totals).max(axis=1)
    return jnp.maximum(alpha, 0)


def rule_heavy_vertex(state: RedState, aux: Aux, heavy_k: int = 8) -> RedState:
    V = state.w.shape[0]
    active = _active(state)
    eact = _edge_active(aux, active)
    deg = _act_deg(aux, eact, V)
    alpha = _alpha_neighborhood(state.w, state.status, aux, heavy_k)
    cand = (
        aux.is_local & active & aux.win_complete
        & (deg <= heavy_k) & (state.w >= alpha)
    )
    acc = _accept_independent(aux, eact, cand, V)
    return _apply_include(state, aux, eact, acc)


# --------------------------------------------------------------------- #
# sweep drivers
# --------------------------------------------------------------------- #
CHEAP_RULES = (
    rule_degree_one,
    rule_neighborhood_removal,
    rule_weight_transfer,
    rule_simplicial,
    rule_basic_single_edge,
    rule_extended_single_edge,
)


def sweep_cheap(state: RedState, aux: Aux) -> RedState:
    """One pass of the cheap rule families, in the paper's §5.1 order."""
    for rule in CHEAP_RULES:
        state = rule(state, aux)
    return state


def sweep_cheap_fused(state: RedState, aux: Aux) -> RedState:
    """Fused sweep: the expensive aggregates (S, deg, M, clique bits) are
    computed ONCE per sweep and shared by all rule families (§Perf H3) —
    tests become conservatively stale, applications stay fresh."""
    ctx = compute_ctx(state, aux)
    for rule in CHEAP_RULES:
        state = rule(state, aux, ctx)
    return state


def reconstruct_members(state: RedState, aux: Aux) -> jax.Array:
    """Replay the fold log in reverse; returns [V] bool membership.

    INCLUDED statuses seed the set; FOLD1 (v ∈ I ⟺ u ∉ I) and WT
    (v ∈ I ⟺ I ∩ N(v) = ∅, window-complete by rule gating) records replay
    newest-first.  All record targets are local by rule construction.
    """
    in_set = state.status == INCLUDED

    def body(i, in_set):
        k = state.log_n - 1 - i
        kind = state.log_kind[k]
        v = state.log_v[k]
        u = state.log_u[k]
        fold1_val = ~in_set[u]
        wt_entries = aux.window[v]
        wt_val = ~(in_set[wt_entries] & (aux.gid[wt_entries] >= 0)).any()
        val = jnp.where(kind == LOG_FOLD1, fold1_val, wt_val)
        return in_set.at[v].set(val)

    return jax.lax.fori_loop(0, state.log_n, body, in_set)


# --------------------------------------------------------------------- #
# seed-faithful drivers (union path) — mirror the seed's local_reduce and
# _disredu_union_jit exactly, parameterised only by the seed's fused flag.
# --------------------------------------------------------------------- #
def local_reduce_oracle(
    state: RedState, aux: Aux, *, heavy_k: int = 8, use_heavy: bool = True,
    max_sweeps: int = 10_000, fused: bool = False,
) -> RedState:
    sweep = sweep_cheap_fused if fused else sweep_cheap

    def body(carry):
        state, _ = carry
        state = state._replace(changed=jnp.zeros((), bool))
        state = sweep(state, aux)
        if use_heavy:
            state = jax.lax.cond(
                state.changed,
                lambda s: s,
                lambda s: rule_heavy_vertex(s, aux, heavy_k),
                state,
            )
        return state, carry[1] + 1

    def cond(carry):
        state, it = carry
        return state.changed & (it < max_sweeps)

    state = state._replace(changed=jnp.ones((), bool))
    state, _ = jax.lax.while_loop(
        cond, body, (state, jnp.zeros((), jnp.int32))
    )
    return state


@functools.partial(
    jax.jit,
    static_argnames=("heavy_k", "use_heavy", "sweeps", "max_rounds", "p",
                     "fused"),
)
def _disredu_union_oracle_jit(
    w0, is_local, is_ghost, aux, halo, *, heavy_k, use_heavy, sweeps,
    max_rounds, p, fused
):
    from repro.core import exchange as X

    state0 = init_state(w0, is_local, is_ghost)

    def body(carry):
        state, rounds, _ = carry
        snap_s, snap_w = state.status, state.w
        state = local_reduce_oracle(
            state, aux, heavy_k=heavy_k, use_heavy=use_heavy,
            max_sweeps=sweeps, fused=fused,
        )
        state, _ = X.exchange_union(state, aux, halo, p=p)
        changed = (state.status != snap_s).any() | (state.w != snap_w).any()
        return state, rounds + 1, changed

    def cond(carry):
        _, rounds, changed = carry
        return changed & (rounds < max_rounds)

    state, rounds, _ = jax.lax.while_loop(
        cond, body, (state0, jnp.zeros((), jnp.int32), jnp.ones((), bool))
    )
    return state, rounds


def disredu_union_oracle(
    pg, *, heavy_k: int = 8, use_heavy: bool = True, mode: str = "sync",
    stale_sweeps: int = 2, max_rounds: int = 10_000, fused: bool = False,
):
    """Seed DisRedu{S,A} on the union layout; returns (state, rounds)."""
    from repro.core.distributed import build_union_problem

    prob = build_union_problem(pg)
    sweeps = 1_000_000 if mode == "sync" else stale_sweeps
    state, rounds = _disredu_union_oracle_jit(
        prob.w0, prob.is_local, prob.is_ghost, prob.aux, prob.halo,
        heavy_k=heavy_k, use_heavy=use_heavy, sweeps=sweeps,
        max_rounds=max_rounds, p=prob.p, fused=fused,
    )
    return state, rounds
