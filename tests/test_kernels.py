"""Pallas kernels: interpret-mode execution vs jnp oracles, shape sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag.kernel import embedding_bag_fused
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.segment_coo.kernel import (
    segment_fused_blocked, segment_sum_blocked,
)
from repro.kernels.segment_coo.ops import (
    pack_blocks, pack_blocks_stacked, segment_fused_coo, segment_sum_coo,
)
from repro.kernels.segment_coo.ref import (
    segment_fused_blocked_ref, segment_sum_blocked_ref,
)
from repro.kernels.wedge_intersect.kernel import wedge_intersect
from repro.kernels.wedge_intersect.ops import common_neighbor_stats
from repro.kernels.wedge_intersect.ref import wedge_intersect_ref


@pytest.mark.parametrize("n_rows,n_edges,d,r_blk", [
    (17, 120, 8, 8), (64, 9, 128, 8), (5, 64, 16, 4), (33, 257, 32, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_coo_kernel_matches_ref(n_rows, n_edges, d, r_blk, dtype):
    rng = np.random.default_rng(0)
    row = rng.integers(0, n_rows, size=n_edges).astype(np.int32)
    data = jnp.asarray(rng.normal(size=(n_edges, d)), dtype)
    edge_perm, lrow, e_blk = pack_blocks(row, n_rows, r_blk=r_blk)
    blocked = data[jnp.asarray(edge_perm.reshape(-1))].reshape(
        edge_perm.shape[0], e_blk, d
    )
    out_k = segment_sum_blocked(
        blocked, jnp.asarray(lrow), r_blk=r_blk, interpret=True
    )
    out_r = segment_sum_blocked_ref(blocked, jnp.asarray(lrow), r_blk=r_blk)
    # bf16: kernel accumulates in f32 via the MXU (preferred_element_type);
    # the jnp ref rounds per-add — kernel is the more accurate of the two
    tol = 1e-6 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        rtol=tol, atol=tol,
    )
    # end-to-end wrapper matches the canonical segment_sum
    got = segment_sum_coo(
        data, jnp.asarray(edge_perm), jnp.asarray(lrow), n_rows,
        r_blk=r_blk, force_pallas=True,
    )
    want = jax.ops.segment_sum(data, jnp.asarray(row), num_segments=n_rows)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("n_rows,n_edges,r_blk", [
    # n_rows > n_edges leaves empty segments → exercises the identities
    (17, 120, 8), (64, 9, 8), (33, 257, 16),
])
def test_segment_fused_kernel_matches_ref_int32(n_rows, n_edges, r_blk):
    """Fused sum+max+min (interpret mode) == blocked ref == jax.ops, exactly
    (int payloads — the aggregate-engine contract is bit-identity)."""
    rng = np.random.default_rng(3)
    row = rng.integers(0, n_rows, size=n_edges).astype(np.int32)
    dsum = jnp.asarray(rng.integers(-500, 500, size=(n_edges, 2)), jnp.int32)
    dmax = jnp.asarray(rng.integers(-500, 500, size=(n_edges, 2)), jnp.int32)
    dmin = jnp.asarray(rng.integers(-500, 500, size=(n_edges, 1)), jnp.int32)
    edge_perm, lrow, e_blk = pack_blocks(row, n_rows, r_blk=r_blk)

    def blocked(d):
        return d[jnp.asarray(edge_perm.reshape(-1))].reshape(
            edge_perm.shape[0], e_blk, d.shape[-1]
        )

    out_k = segment_fused_blocked(
        blocked(dsum), blocked(dmax), blocked(dmin), jnp.asarray(lrow),
        r_blk=r_blk, interpret=True,
    )
    out_r = segment_fused_blocked_ref(
        blocked(dsum), blocked(dmax), blocked(dmin), jnp.asarray(lrow),
        r_blk=r_blk,
    )
    for k, r in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(k), np.asarray(r))
    # end-to-end wrapper (pallas-interpret) == canonical jax.ops semantics
    got = segment_fused_coo(
        jnp.asarray(edge_perm), jnp.asarray(lrow), n_rows,
        data_sum=dsum, data_max=dmax, data_min=dmin,
        r_blk=r_blk, force_pallas=True,
    )
    seg = jnp.asarray(row)
    want = (
        jax.ops.segment_sum(dsum, seg, num_segments=n_rows),
        jax.ops.segment_max(dmax, seg, num_segments=n_rows),
        jax.ops.segment_min(dmin, seg, num_segments=n_rows),
    )
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_segment_fused_partial_payloads_and_ref_dispatch():
    """Absent payload groups come back as None on both dispatch paths."""
    rng = np.random.default_rng(4)
    n_rows, n_edges = 23, 77
    row = rng.integers(0, n_rows, size=n_edges).astype(np.int32)
    dmax = jnp.asarray(rng.integers(0, 100, size=(n_edges, 3)), jnp.int32)
    edge_perm, lrow, _ = pack_blocks(row, n_rows, r_blk=8)
    want = jax.ops.segment_max(dmax, jnp.asarray(row), num_segments=n_rows)
    for force in (True, False):
        s, m, n, o = segment_fused_coo(
            jnp.asarray(edge_perm), jnp.asarray(lrow), n_rows,
            data_max=dmax, force_pallas=force,
        )
        assert s is None and n is None and o is None
        np.testing.assert_array_equal(np.asarray(m), np.asarray(want))


@pytest.mark.parametrize("n_rows,n_edges,r_blk,nbits", [
    (17, 120, 8, 12), (33, 257, 16, 16), (64, 9, 8, 5),
])
def test_segment_fused_or_payloads(n_rows, n_edges, r_blk, nbits):
    """Bitwise-OR payload group (kernel bitplane matmul + blocked ref + the
    generic jnp fallback) == per-segment np.bitwise_or, exactly."""
    from repro.kernels.segment_coo.ref import segment_or_ref

    rng = np.random.default_rng(11)
    row = rng.integers(0, n_rows, size=n_edges).astype(np.int32)
    dor = rng.integers(0, 1 << nbits, size=(n_edges, 2)).astype(np.int32)
    dsum = rng.integers(-9, 9, size=(n_edges, 1)).astype(np.int32)
    edge_perm, lrow, _ = pack_blocks(row, n_rows, r_blk=r_blk)
    want = np.zeros((n_rows, 2), np.int32)
    for e in range(n_edges):
        want[row[e]] |= dor[e]
    for force in (True, False):
        s, _, _, o = segment_fused_coo(
            jnp.asarray(edge_perm), jnp.asarray(lrow), n_rows,
            data_sum=jnp.asarray(dsum), data_or=jnp.asarray(dor),
            or_nbits=nbits, r_blk=r_blk, force_pallas=force,
        )
        np.testing.assert_array_equal(np.asarray(o), want)
        np.testing.assert_array_equal(
            np.asarray(s),
            np.asarray(jax.ops.segment_sum(
                jnp.asarray(dsum), jnp.asarray(row), num_segments=n_rows
            )),
        )
    got = segment_or_ref(
        jnp.asarray(dor), jnp.asarray(row), n_rows, nbits=nbits
    )
    np.testing.assert_array_equal(np.asarray(got), want)


def test_pack_blocks_stacked_shared_budget():
    """Stacked packing pads every PE to one shared E_BLK and each PE's plan
    reproduces its own per-PE packing semantics."""
    rng = np.random.default_rng(5)
    p, E, n_rows = 3, 64, 19
    rows = rng.integers(0, n_rows, size=(p, E)).astype(np.int32)
    perm, lrow, e_blk = pack_blocks_stacked(rows, n_rows, r_blk=8)
    n_blocks = (n_rows + 8 - 1) // 8
    assert perm.shape == lrow.shape == (p, n_blocks, e_blk)
    for i in range(p):
        data = jnp.asarray(
            rng.integers(-9, 9, size=(E, 1)), jnp.int32
        )
        got, _, _, _ = segment_fused_coo(
            jnp.asarray(perm[i]), jnp.asarray(lrow[i]), n_rows,
            data_sum=data, force_pallas=False,
        )
        want = jax.ops.segment_sum(
            data, jnp.asarray(rows[i]), num_segments=n_rows
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("E,D,e_blk", [(100, 8, 32), (513, 16, 256), (7, 4, 8)])
def test_wedge_intersect_kernel_matches_ref(E, D, e_blk):
    rng = np.random.default_rng(1)
    V = 50
    wu = rng.integers(0, V + 1, size=(E, D)).astype(np.int32)
    wv = rng.integers(0, V + 1, size=(E, D)).astype(np.int32)
    awu = rng.integers(0, 200, size=(E, D)).astype(np.int32)
    actu = rng.integers(0, 2, size=(E, D)).astype(np.int32)
    c_k, k_k = wedge_intersect(
        jnp.asarray(wu), jnp.asarray(wv), jnp.asarray(awu),
        jnp.asarray(actu), e_blk=e_blk, interpret=True,
    )
    c_r, k_r = wedge_intersect_ref(
        jnp.asarray(wu), jnp.asarray(wv), jnp.asarray(awu), jnp.asarray(actu)
    )
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
    np.testing.assert_array_equal(np.asarray(k_k), np.asarray(k_r))


def test_wedge_ops_counts_common_neighbors():
    """C/K from the ops wrapper equal a direct set computation."""
    from repro.core import partition as part
    from repro.graphs import generators as gen

    g = gen.random_graph(30, 0.3, seed=7)
    pg = part.partition_graph(g, 1, window_cap=8)
    window = jnp.asarray(pg.window[0])
    weights = jnp.asarray(pg.w0[0])
    active = jnp.asarray(pg.is_local[0] | pg.is_ghost[0])
    row = jnp.asarray(pg.row[0])
    col = jnp.asarray(pg.col[0])
    c, k = common_neighbor_stats(
        window, weights, active, row, col, force_pallas=True
    )
    c = np.asarray(c)
    for e in range(pg.E):
        r, cc = int(pg.row[0, e]), int(pg.col[0, e])
        if r == pg.nil:
            continue
        nr = set(g.neighbors(int(pg.gid[0, r])).tolist())
        nc = set(g.neighbors(int(pg.gid[0, cc])).tolist())
        common = nr & nc
        if g.degree(int(pg.gid[0, r])) <= 8 and g.degree(int(pg.gid[0, cc])) <= 8:
            want = sum(int(g.weights[x]) for x in common)
            assert c[e] == want, e


@pytest.mark.parametrize("V,B,K,D,b_blk", [
    (100, 33, 4, 16, 8), (64, 8, 1, 128, 4), (500, 70, 7, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_kernel_matches_ref(V, B, K, D, b_blk, dtype):
    rng = np.random.default_rng(2)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype)
    idx = jnp.asarray(rng.integers(0, V, size=(B, K)), jnp.int32)
    wgt = jnp.asarray(rng.normal(size=(B, K)), jnp.float32)
    out_k = embedding_bag_fused(table, idx, wgt, b_blk=b_blk, interpret=True)
    out_r = embedding_bag_ref(table, idx, wgt)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        rtol=tol, atol=tol,
    )
