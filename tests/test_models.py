"""Model substrate: flash attention (fwd+bwd), chunked loss, arch smokes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import common as C


def naive_attention(q, k, v, causal=True, window=None):
    B, T, H, Dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    qs = q.reshape(B, T, Hkv, rep, Dh) / np.sqrt(Dh)
    s = jnp.einsum(
        "btgrd,bsgd->btgrs", qs.astype(jnp.float32), k.astype(jnp.float32)
    )
    qpos, kpos = jnp.arange(T), jnp.arange(S)
    m = jnp.ones((T, S), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        m &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(m[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("btgrs,bsgd->btgrd", p, v.astype(jnp.float32))
    return o.reshape(B, T, H, Dh)


@pytest.mark.parametrize("B,T,H,Hkv,Dh,chunk,window", [
    (2, 37, 4, 2, 8, 16, None),
    (1, 64, 4, 1, 16, 16, 9),
    (2, 33, 2, 2, 8, 8, None),
    (1, 100, 8, 4, 4, 32, 25),
])
def test_flash_attention_fwd_and_grads(B, T, H, Hkv, Dh, chunk, window):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, T, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, Dh)), jnp.float32)
    w = None if window is None else jnp.asarray(window)
    ref = naive_attention(q, k, v, window=window)
    out = C.flash_attention(q, k, v, w, chunk=chunk)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5
    )
    gr = jax.grad(
        lambda *a: (naive_attention(*a, window=window) ** 2).sum(),
        argnums=(0, 1, 2),
    )(q, k, v)
    gf = jax.grad(
        lambda *a: (C.flash_attention(*a, w, chunk=chunk)
                    .astype(jnp.float32) ** 2).sum(),
        argnums=(0, 1, 2),
    )(q, k, v)
    for a, b in zip(gr, gf):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), rtol=3e-4, atol=3e-4
        )


def test_chunked_attention_oracle_agrees():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, 40, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 40, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 40, 2, 8)), jnp.float32)
    a = C.chunked_attention(q, k, v, chunk=16)
    b = C.flash_attention(q, k, v, chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


def test_chunked_xent_matches_direct():
    rng = np.random.default_rng(2)
    B, T, D, V = 2, 16, 8, 50
    h = jnp.asarray(rng.normal(size=(B, T, D)), jnp.float32)
    emb = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, size=(B, T)), jnp.int32)
    got = C.chunked_xent(h, emb, labels, n_chunks=4)
    logits = h @ emb.T
    want = (jax.nn.logsumexp(logits, -1)
            - jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
            ).mean()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
    # gradient flows and matches
    g1 = jax.grad(lambda h: C.chunked_xent(h, emb, labels, n_chunks=4))(h)
    g2 = jax.grad(
        lambda h: (jax.nn.logsumexp(h @ emb.T, -1) - jnp.take_along_axis(
            h @ emb.T, labels[..., None], -1)[..., 0]).mean()
    )(h)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4,
                               atol=1e-5)


def test_rope_rotation_properties():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(1, 6, 2, 8)), jnp.float32)
    pos = jnp.arange(6)[None, :]
    y = C.rope(x, pos)
    # norm preservation per (pair) rotation
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5,
    )
    # position 0 is identity
    np.testing.assert_allclose(
        np.asarray(y[:, 0]), np.asarray(x[:, 0]), rtol=1e-6
    )


@pytest.mark.parametrize("arch_id", sorted(registry.ARCHS))
def test_arch_smoke(arch_id):
    """Reduced-config forward/train step per assigned architecture."""
    registry.get(arch_id).smoke()
