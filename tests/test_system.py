"""End-to-end system behaviour: the paper's full pipeline, input → solution.

The central property (the paper's Theorems 4.x composed): for any graph and
any PE count, DisRedu{S,A} + residual solve + reconstruction yields an
independent set whose weight equals the exact MWIS weight.
"""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import distributed as D
from repro.core import partition as part
from repro.core import sequential as seq
from repro.core import solvers as S
from repro.core.bitset_mwis import mwis_exact
from repro.graphs import generators as gen
from tests.helpers import SMALL_PAD, residual_exact_weight


@settings(max_examples=30, deadline=None)
@given(
    st.integers(0, 1_000_000),
    st.sampled_from([1, 2, 4]),
    st.sampled_from(["sync", "async"]),
)
def test_end_to_end_reduction_is_exact(seed, p, mode):
    """reduce → exact residual → reconstruct == brute force, any p/mode."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 13))
    g = gen.random_graph(n, float(rng.uniform(0.1, 0.75)), seed=seed)
    best, _ = mwis_exact(g)
    pg = part.partition_graph(g, p, window_cap=8, common_cap=4,
                              pad_to=SMALL_PAD)
    state, prob, _ = D.disredu(
        pg, D.DisReduConfig(heavy_k=6, mode=mode, max_rounds=300)
    )
    wgt, indep = residual_exact_weight(g, pg, state, prob)
    assert indep
    assert wgt == best


def test_full_pipeline_on_weak_scaling_families():
    """GNM barely reduces, RGG partially, RHG strongly (paper Table C.4)."""
    impact = {}
    for name in ("gnm", "rgg", "rhg"):
        g = gen.FAMILIES[name](1500, seed=0)
        pg = part.partition_graph(g, 4, window_cap=12)
        state, prob, _ = D.disredu(pg, D.DisReduConfig(heavy_k=8))
        nv, _ = D.kernel_stats(pg, state)
        impact[name] = nv / g.n
    assert impact["gnm"] > impact["rgg"] > impact["rhg"]
    assert impact["rhg"] < 0.7


def test_all_solvers_produce_valid_solutions_all_modes():
    g = gen.rgg2d(600, avg_deg=8, seed=2)
    weights = {}
    for algo in ("greedy", "rg", "rnp"):
        for mode in ("sync", "async"):
            pg = part.partition_graph(g, 4, window_cap=12)
            members, _ = S.solve(
                pg, algo, D.DisReduConfig(heavy_k=6, mode=mode)
            )
            assert g.is_independent_set(members)
            weights[(algo, mode)] = g.set_weight(members)
    # reduce-and-peel dominates plain greedy (paper Table 7.1 ordering)
    assert weights[("rnp", "sync")] >= weights[("greedy", "sync")]
    assert weights[("rnp", "async")] >= weights[("greedy", "async")]


def test_solution_quality_vs_sequential_baseline():
    """Distributed RnPA vs the HtWIS-style sequential baseline (Table 7.1:
    distributed keeps ≥97% at large p; we assert a conservative 93%)."""
    rat = []
    for seed in range(3):
        g = gen.rgg2d(700, avg_deg=8, seed=seed)
        w_seq, _ = seq.solve_reduce_and_peel(g)
        pg = part.partition_graph(g, 8, window_cap=12)
        members, _ = S.solve(
            pg, "rnp", D.DisReduConfig(heavy_k=6, mode="async")
        )
        rat.append(g.set_weight(members) / max(w_seq, 1))
    assert np.mean(rat) > 0.93, rat


def test_offset_accounting_consistent():
    """Σ original weights over reconstructed members == reported kernel
    value + offsets when the kernel is solved exactly (small instance)."""
    g = gen.random_graph(12, 0.4, seed=9)
    best, _ = mwis_exact(g)
    pg = part.partition_graph(g, 2, window_cap=8, pad_to=SMALL_PAD)
    state, prob, _ = D.disredu(pg, D.DisReduConfig(heavy_k=6))
    wgt, indep = residual_exact_weight(g, pg, state, prob)
    assert indep and wgt == best


def test_kernel_compaction_driver():
    """Shape descent (reduce → measure kernel → restrict onto a smaller
    ladder cell → continue) stays sound and matches plain RnP bit for
    bit — compaction is an exact restriction, not a heuristic."""
    g = gen.rgg2d(1200, avg_deg=8, seed=4)
    cfg = D.DisReduConfig(mode="async", heavy_k=6)
    pg = part.partition_graph(g, 4, window_cap=12)
    m_plain, _ = S.solve(pg, "rnp", cfg)
    dcfg = D.DisReduConfig(mode="async", heavy_k=6, descent=True,
                           descent_every=2)
    m_comp, stats = S.solve_staged(g, 4, "rnp", dcfg, window_cap=12)
    assert g.is_independent_set(m_comp)
    assert stats["descents"] >= 1
    assert stats["kernel_ratio"] < 1.0
    assert np.array_equal(m_comp, m_plain)
