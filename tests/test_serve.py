"""Batched MWIS serving layer: cache semantics, vmap invariance, bucketing,
CLI validation, and the bench-regression gate (benchmarks/compare.py)."""

import copy
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import engine as E
from repro.core import serve as SV
from repro.core import solvers as SOL
from repro.core.distributed import DisReduConfig
from repro.core.partition import partition_graph
from repro.graphs.generators import gnm

# --------------------------------------------------------------------- #
# helpers
# --------------------------------------------------------------------- #


def _reweighted(g, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(1, 201, size=g.n).astype(np.int32)
    return type(g)(indptr=g.indptr, indices=g.indices, weights=w)


def _oracle(g, algo, backend):
    """The unbatched single-instance path on the same cell shapes."""
    cell = SV.bucket_for(g.n, g.num_directed_edges)
    pg = partition_graph(
        g, 1, window_cap=cell.D, common_cap=cell.Dc,
        pad_to=dict(L=cell.L, G=cell.G, E=cell.E, B=cell.B, S=cell.S),
    )
    cfg = DisReduConfig(
        backend=backend, r_blk=None if backend == "jnp" else cell.r_blk
    )
    members, _ = SOL.solve(pg, algo, cfg)
    return members


# --------------------------------------------------------------------- #
# PlanCache semantics
# --------------------------------------------------------------------- #


def test_plan_cache_lru_eviction_bound():
    c = E.PlanCache(max_entries=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1       # refreshes recency: b is now oldest
    c.put("c", 3)                # evicts b
    assert len(c) == 2
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    s = c.stats
    assert s.evictions == 1 and s.size == 2


def test_plan_cache_raising_build_does_not_poison():
    """A build() that raises leaves NO entry behind: the miss is counted
    once, the error is counted, and a later successful build repopulates."""
    c = E.PlanCache(max_entries=4)
    calls = [0]

    def bad():
        calls[0] += 1
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError, match="boom"):
        c.get_or_build("k", bad)
    s = c.stats
    assert len(c) == 0            # nothing cached for "k"
    assert s.misses == 1 and s.errors == 1 and s.hits == 0

    assert c.get_or_build("k", lambda: 42) == 42   # retry rebuilds
    assert c.get_or_build("k", bad) == 42          # now a hit; bad not called
    assert calls[0] == 1
    s = c.stats
    assert s.errors == 1 and s.hits == 1 and s.misses == 2


def test_topology_hash_semantics():
    g = gnm(30, 60, seed=0)
    row, col = g.edge_sources(), g.indices
    h0 = E.topology_hash(row, col, g.n)
    # permutation of the same edge multiset -> same hash
    perm = np.random.default_rng(0).permutation(row.shape[0])
    assert E.topology_hash(row[perm], col[perm], g.n) == h0
    # removing an edge (both directions) -> different hash
    keep = ~(((row == row[0]) & (col == col[0]))
             | ((row == col[0]) & (col == row[0])))
    assert E.topology_hash(row[keep], col[keep], g.n) != h0
    # different vertex budget -> different hash
    assert E.topology_hash(row, col, g.n + 1) != h0


def test_service_cache_hit_miss_semantics():
    svc = SV.MWISService(SV.ServeConfig(algo="rg", backend="jnp"))
    g = gnm(24, 50, seed=1)
    svc.solve_one(g)
    assert svc.stats["cache_misses"] == 1
    # identical topology -> hit
    svc.solve_one(g)
    assert svc.stats["cache_hits"] == 1
    # weights-only change -> still a hit (topology key excludes weights)
    svc.solve_one(_reweighted(g, 7))
    assert svc.stats["cache_hits"] == 2
    assert svc.stats["cache_misses"] == 1
    # edge change -> miss
    svc.solve_one(gnm(24, 51, seed=1))
    assert svc.stats["cache_misses"] == 2


def test_service_cache_eviction_bound():
    svc = SV.MWISService(
        SV.ServeConfig(algo="rg", backend="jnp", cache_entries=2)
    )
    for s in range(4):
        svc.solve_one(gnm(20, 40, seed=s))
    st = svc.stats
    assert st["cache_size"] <= 2
    assert st["cache_evictions"] == 2


def test_cached_topology_reuse_is_bit_identical():
    svc = SV.MWISService(SV.ServeConfig(algo="rg", backend="jnp"))
    g = gnm(26, 55, seed=3)
    first = svc.solve_one(g)
    again = svc.solve_one(g)          # served from cache
    assert np.array_equal(first.members, again.members)
    assert first.weight == again.weight


# --------------------------------------------------------------------- #
# vmap invariance: batched == sequence of single-instance runs, per backend
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("backend", ["jnp", "blocked", "pallas"])
@pytest.mark.parametrize("algo", ["greedy", "rg"])
def test_batched_matches_single_instance(backend, algo):
    k = 2 if backend == "pallas" else 4
    graphs = [gnm(18 + 3 * i, 40 + 4 * i, seed=i) for i in range(k)]
    svc = SV.MWISService(SV.ServeConfig(algo=algo, backend=backend))
    res = svc.solve_batch(graphs)
    for g, r in zip(graphs, res):
        ref = _oracle(g, algo, backend)
        assert np.array_equal(r.members, ref), (backend, algo, g.n)


def test_batched_rnp_matches_single_instance():
    graphs = [gnm(20 + 2 * i, 45, seed=10 + i) for i in range(3)]
    svc = SV.MWISService(SV.ServeConfig(algo="rnp", backend="jnp"))
    for g, r in zip(graphs, svc.solve_batch(graphs)):
        assert np.array_equal(r.members, _oracle(g, "rnp", "jnp"))


def test_results_are_independent_sets_with_reported_weight():
    graphs = [gnm(30, 70, seed=20 + i) for i in range(5)]
    svc = SV.MWISService(SV.ServeConfig(algo="rg", backend="jnp"))
    for g, r in zip(graphs, svc.solve_batch(graphs)):
        src = g.edge_sources()
        assert not np.any(r.members[src] & r.members[g.indices])
        assert r.weight == int(g.weights[r.members].sum())
        assert r.members.shape == (g.n,)


def test_mixed_cell_batch_and_padding():
    # instances landing in different cells within one solve_batch call,
    # with a group size that is not a static batch bucket (padding path)
    graphs = [gnm(20, 40, seed=30), gnm(22, 44, seed=31),
              gnm(24, 48, seed=32), gnm(120, 300, seed=33)]
    svc = SV.MWISService(SV.ServeConfig(algo="rg", backend="jnp"))
    res = svc.solve_batch(graphs)
    assert [r.members.shape[0] for r in res] == [g.n for g in graphs]
    for g, r in zip(graphs, res):
        assert np.array_equal(r.members, _oracle(g, "rg", "jnp"))


# --------------------------------------------------------------------- #
# bucketing
# --------------------------------------------------------------------- #


def test_bucket_for_picks_smallest_admitting_cell():
    cells = SV.serve_cells()
    assert len(cells) >= 3
    assert SV.bucket_for(10, 20).name == cells[0].name
    # vertex count forces the next cell up even with few edges
    nxt = SV.bucket_for(cells[0].L + 1, 8)
    assert nxt.name == cells[1].name
    # edge count alone forces promotion too
    assert SV.bucket_for(8, cells[0].E + 2).name == cells[1].name


def test_bucket_for_rejects_oversized_instance():
    big = SV.serve_cells()[-1]
    with pytest.raises(ValueError, match="exceeds every serve cell"):
        SV.bucket_for(big.L + 1, 4)


@pytest.mark.parametrize("backend", ["jnp", "blocked"])
def test_aggregate_batched_matches_per_instance(backend):
    import jax.numpy as jnp

    rng = np.random.default_rng(2)
    n_rows, n_edges, B = 16, 48, 3
    seg = np.sort(rng.integers(0, n_rows, size=n_edges)).astype(np.int32)
    data = rng.integers(0, 1000, size=(B, n_edges)).astype(np.int32)
    plan = None
    if backend == "blocked":
        base_plan = E.build_plan(seg, n_rows, r_blk=8)
        plan = E.stack_plans([base_plan] * B)
    seg_b = jnp.asarray(np.broadcast_to(seg, (B, n_edges)).copy())
    s, m, *_ = E.aggregate_batched(
        seg_b, n_rows,
        data_sum=jnp.asarray(data), data_max=jnp.asarray(data),
        backend=backend, plan=plan,
    )
    for i in range(B):
        si, mi, *_ = E.aggregate(
            jnp.asarray(seg), n_rows, data_sum=jnp.asarray(data[i]),
            data_max=jnp.asarray(data[i]), backend=backend,
            plan=None if plan is None else base_plan,
        )
        assert np.array_equal(np.asarray(s[i]), np.asarray(si))
        assert np.array_equal(np.asarray(m[i]), np.asarray(mi))


def test_plan_stacking_bit_identity():
    # pad_plan slots follow the pack_blocks convention -> identical result
    g = gnm(40, 100, seed=5)
    pg = partition_graph(g, 1, window_cap=8, common_cap=4)
    row = np.asarray(pg.row[0])
    plan = E.build_plan(row, pg.V, r_blk=8)
    import jax.numpy as jnp
    data = np.random.default_rng(0).integers(0, 100, row.shape[0])
    data = jnp.asarray(data, jnp.int32)
    s0, _, _, _ = E.aggregate(jnp.asarray(row), pg.V, data_sum=data,
                              backend="blocked", plan=plan)
    padded = E.pad_plan(plan, plan.edge_perm.shape[1] + 24)
    s1, _, _, _ = E.aggregate(jnp.asarray(row), pg.V, data_sum=data,
                              backend="blocked", plan=padded)
    assert np.array_equal(np.asarray(s0), np.asarray(s1))


# --------------------------------------------------------------------- #
# CLI validation
# --------------------------------------------------------------------- #


def test_serve_cli_rejects_unknown_arch(capsys):
    from repro.launch import serve as L

    with pytest.raises(SystemExit) as e:
        L.main(["--arch", "nope"])
    assert e.value.code == 2
    err = capsys.readouterr().err
    for arch in L.ARCHES:
        assert arch in err  # the error lists every valid choice


# --------------------------------------------------------------------- #
# bench-regression gate (benchmarks/compare.py)
# --------------------------------------------------------------------- #

BASE = dict(
    meta={},
    results=[dict(
        graph="g1", n=100, m=200, p=2, schedule="cheap-fused",
        per_sweep_us={"jnp": 100.0, "blocked-auto": 200.0,
                      "pallas-interpret": 5000.0, "seed-fused-jnp": 110.0},
        greedy_round_us={"jnp": 50.0, "blocked-auto": 90.0},
        rnp_round_us={"jnp": 70.0},
    )],
)


def _run_compare(tmp_path, baseline, fresh, argv_extra=()):
    from benchmarks import compare as C

    b = tmp_path / "base.json"
    f = tmp_path / "fresh.json"
    out = tmp_path / "diff.json"
    b.write_text(json.dumps(baseline))
    f.write_text(json.dumps(fresh))
    rc = C.main([str(b), str(f), "--out", str(out), *argv_extra])
    return rc, json.loads(out.read_text())


def test_compare_clean_run_passes(tmp_path):
    rc, diff = _run_compare(tmp_path, BASE, copy.deepcopy(BASE))
    assert rc == 0
    assert diff["regressions"] == []
    assert any(c["gated"] for c in diff["cells"])


def test_compare_synthetic_2x_slowdown_fails(tmp_path):
    slow = copy.deepcopy(BASE)
    slow["results"][0]["per_sweep_us"]["jnp"] *= 2.0
    rc, diff = _run_compare(tmp_path, BASE, slow)
    assert rc == 1
    assert len(diff["regressions"]) == 1
    r = diff["regressions"][0]
    assert r["label"] == "jnp" and r["normalized"]


def test_compare_solver_round_regression_fails(tmp_path):
    slow = copy.deepcopy(BASE)
    slow["results"][0]["greedy_round_us"]["blocked-auto"] *= 3.0
    rc, diff = _run_compare(tmp_path, BASE, slow)
    assert rc == 1
    assert diff["regressions"][0]["metric"] == "greedy_round_us"


def test_compare_pallas_regression_warns_only(tmp_path):
    slow = copy.deepcopy(BASE)
    slow["results"][0]["per_sweep_us"]["pallas-interpret"] *= 10.0
    rc, diff = _run_compare(tmp_path, BASE, slow)
    assert rc == 0
    assert diff["regressions"] == []
    assert len(diff["warnings"]) == 1
    assert diff["warnings"][0]["label"] == "pallas-interpret"


def test_compare_normalization_cancels_machine_speed(tmp_path):
    # a uniformly 3x-slower machine (every metric AND the seed reference
    # scaled together) must NOT trip the gate
    slow = copy.deepcopy(BASE)
    row = slow["results"][0]
    for metric in ("per_sweep_us", "greedy_round_us", "rnp_round_us"):
        row[metric] = {k: v * 3.0 for k, v in row[metric].items()}
    rc, diff = _run_compare(tmp_path, BASE, slow)
    assert rc == 0
    assert diff["regressions"] == [] and diff["warnings"] == []


def test_compare_threshold_is_configurable(tmp_path):
    slow = copy.deepcopy(BASE)
    slow["results"][0]["per_sweep_us"]["jnp"] *= 1.3
    rc, _ = _run_compare(tmp_path, BASE, slow)
    assert rc == 0                    # 1.3x under default 1.5
    rc, _ = _run_compare(tmp_path, BASE, slow,
                         argv_extra=("--threshold", "1.2"))
    assert rc == 1


def test_compare_missing_rows_warn_not_fail(tmp_path):
    fresh = copy.deepcopy(BASE)
    fresh["results"] = []             # CI small mode ran a subset
    rc, diff = _run_compare(tmp_path, BASE, fresh)
    assert rc == 0
    assert diff["missing"]


SERVE_BASE = dict(
    meta={},
    results=[dict(cell="serve_xs", backend="jnp", batch=4,
                  instances_per_sec=50.0)],
    multidevice=[dict(cell="serve_xs", backend="jnp", batch=4, devices=4,
                      instances_per_sec=80.0, overlap_ratio=0.2)],
)


def test_compare_serve_slowdown_warns_but_never_gates(tmp_path):
    from benchmarks import compare as C

    slow = copy.deepcopy(SERVE_BASE)
    slow["results"][0]["instances_per_sec"] = 10.0    # 5x slower
    slow["multidevice"][0]["instances_per_sec"] = 10.0
    sb = tmp_path / "sbase.json"
    sf = tmp_path / "sfresh.json"
    sb.write_text(json.dumps(SERVE_BASE))
    sf.write_text(json.dumps(slow))
    b = tmp_path / "base.json"
    f = tmp_path / "fresh.json"
    out = tmp_path / "diff.json"
    b.write_text(json.dumps(BASE))
    f.write_text(json.dumps(BASE))
    rc = C.main([str(b), str(f), "--out", str(out),
                 "--serve-baseline", str(sb), "--serve-fresh", str(sf)])
    assert rc == 0                      # serve section never gates
    diff = json.loads(out.read_text())
    assert len(diff["serve"]["warnings"]) == 2
    assert all(not r["gated"] for r in diff["serve"]["rows"])
    # committed baselines without a devices column compare as devices=1
    assert {r["devices"] for r in diff["serve"]["rows"]} == {1, 4}


def test_compare_serve_missing_and_new_rows_warn_only(tmp_path):
    from benchmarks import compare as C

    fresh = dict(meta={}, results=[], multidevice=[
        dict(cell="serve_s", backend="jnp", batch=16, devices=4,
             instances_per_sec=5.0, overlap_ratio=0.1)])
    diff = C.compare_serve(SERVE_BASE, fresh, threshold=1.5)
    assert diff["warnings"] == []
    assert len(diff["missing"]) == 2    # both baseline rows absent
    new = [r for r in diff["rows"] if r["baseline_ips"] is None]
    assert len(new) == 1 and new[0]["cell"] == "serve_s"


# --------------------------------------------------------------------- #
# multi-device batch sharding + overlapped host pipeline
# --------------------------------------------------------------------- #


def test_batch_size_rounds_to_device_multiple():
    svc = SV.MWISService(SV.ServeConfig(backend="jnp"))
    svc._ndev = 4                       # as if 4 devices were visible
    assert svc._batch_size(1) == 4      # bucket 1 rounds up to a shardable 4
    assert svc._batch_size(3) == 4
    assert svc._batch_size(5) == 16     # bucket 16 already a multiple
    cell = svc.cells[0]._replace(serve_devices=2)
    assert svc._cell_ndev(cell) == 2    # per-cell cap wins over the mesh
    assert svc._batch_size(1, cell) == 2
    svc._ndev = 1
    assert svc._batch_size(1) == 1      # single device: buckets unchanged
    assert svc._batch_size(5) == 16


def test_batch_size_respects_max_batch_fallthrough():
    svc = SV.MWISService(SV.ServeConfig(backend="jnp", max_batch=8))
    svc._ndev = 4
    # no static bucket fits in (7, 8] -> fall through, still device-aligned
    assert svc._batch_size(7) == 8
    assert svc._batch_size(7) % 4 == 0


def test_stack_plans_pads_to_batch_multiple():
    g = gnm(40, 100, seed=5)
    pg = partition_graph(g, 1, window_cap=8, common_cap=4)
    row = np.asarray(pg.row[0])
    plan = E.build_plan(row, pg.V, r_blk=8)
    stacked = E.stack_plans([plan] * 3, batch_multiple=4)
    assert stacked.edge_perm.shape[0] == 4    # 3 plans padded to 4
    # phantom slot repeats the last plan bit-for-bit
    assert np.array_equal(np.asarray(stacked.edge_perm[3]),
                          np.asarray(stacked.edge_perm[2]))
    same = E.stack_plans([plan] * 4, batch_multiple=4)
    assert same.edge_perm.shape[0] == 4       # already aligned: no padding
    with pytest.raises(ValueError, match="batch_multiple"):
        E.stack_plans([plan], batch_multiple=0)


def test_service_rejects_excess_devices():
    with pytest.raises(ValueError, match="exceeds the .* visible"):
        SV.MWISService(SV.ServeConfig(backend="jnp", devices=4096))


def test_serve_cli_rejects_excess_devices(capsys):
    from repro.launch import serve as L

    with pytest.raises(SystemExit) as e:
        L.main(["--arch", "mwis", "--devices", "4096"])
    assert e.value.code == 2
    assert "visible" in capsys.readouterr().err


def test_pipeline_parity_and_stage_stats():
    # multi-chunk call: pipeline on and off must be bit-identical, and the
    # per-stage timing telemetry must cover every chunk either way
    graphs = [gnm(18 + 2 * i, 40 + 3 * i, seed=50 + i) for i in range(6)]
    on = SV.MWISService(SV.ServeConfig(backend="jnp", max_batch=2,
                                       pipeline=True))
    off = SV.MWISService(SV.ServeConfig(backend="jnp", max_batch=2,
                                        pipeline=False))
    r_on = on.solve_batch(graphs)
    r_off = off.solve_batch(graphs)
    for a, b in zip(r_on, r_off):
        assert a.ok and b.ok
        assert a.weight == b.weight
        assert np.array_equal(a.members, b.members)
    s_on, s_off = on.stats, off.stats
    assert s_on["pipelined_chunks"] == s_on["chunks"] == 3
    assert s_off["pipelined_chunks"] == 0 and s_off["chunks"] == 3
    for s in (s_on, s_off):
        assert s["stage_ms"]["pack"] > 0 and s["stage_ms"]["solve"] > 0
        assert set(s["stage_p50_ms"]) == {"pack", "transfer", "solve",
                                          "fetch"}
        assert s["wall_ms"] > 0 and 0.0 <= s["overlap_ratio"] < 1.0


def test_pipeline_single_chunk_takes_sync_path():
    # one chunk has nothing to overlap with -> the sync path runs (this
    # also keeps the _execute_chunk monkeypatch seam on solve_one)
    svc = SV.MWISService(SV.ServeConfig(backend="jnp"))
    r = svc.solve_one(gnm(20, 40, seed=60))
    assert r.ok
    assert svc.stats["pipelined_chunks"] == 0 and svc.stats["chunks"] == 1


def test_pipeline_poisoned_batchmates_are_isolated():
    from repro.core import validate as VAL
    from repro.core.graph import Graph

    good = [gnm(20, 40, seed=70 + s) for s in range(5)]
    nan_g = Graph(indptr=np.array([0, 1, 2]),
                  indices=np.array([1, 0], np.int32),
                  weights=np.array([np.nan, 1.0]))
    svc = SV.MWISService(SV.ServeConfig(backend="jnp", max_batch=2,
                                        pipeline=True))
    res = svc.solve_batch([good[0], good[1], nan_g, good[2], good[3],
                           good[4]])
    assert not res[2].ok and res[2].reason == VAL.REASON_BAD_WEIGHT
    ref = SV.MWISService(SV.ServeConfig(backend="jnp")).solve_batch(good)
    for got, want in zip([res[0], res[1], res[3], res[4], res[5]], ref):
        assert got.ok and np.array_equal(got.members, want.members)


def test_pipeline_dispatch_failure_falls_back_to_sync_path(monkeypatch):
    # a launch that raises mid-pipeline must not lose the chunk: it is
    # retired through the synchronous fallback-chain path
    graphs = [gnm(18 + 2 * i, 40, seed=80 + i) for i in range(4)]
    svc = SV.MWISService(SV.ServeConfig(backend="jnp", max_batch=2,
                                        pipeline=True))
    ref = SV.MWISService(
        SV.ServeConfig(backend="jnp", max_batch=2, pipeline=False)
    ).solve_batch(graphs)
    boom = {"n": 0}
    real = SV.MWISService._launch_chunk

    def flaky(self, staged):
        boom["n"] += 1
        if boom["n"] == 1:
            raise RuntimeError("injected launch failure")
        return real(self, staged)

    monkeypatch.setattr(SV.MWISService, "_launch_chunk", flaky)
    res = svc.solve_batch(graphs)
    for got, want in zip(res, ref):
        assert got.ok and np.array_equal(got.members, want.members)
    assert svc.stats["pipeline_retries"] == 1


def test_descent_auto_takes_staged_single_device_path():
    # descent-routed instances bypass the sharded/pipelined chunk machinery
    # entirely (per-instance staged path) and still solve correctly
    cells = SV.serve_cells()
    big = cells[-1]
    n = big.L // 2 + 8
    g = gnm(n, 2 * n, seed=90)
    svc = SV.MWISService(SV.ServeConfig(
        backend="jnp", descent="auto", descent_min_L=big.L))
    r = svc.solve_batch([g])[0]
    assert r.ok
    assert svc.stats["descent_solves"] == 1
    assert svc.stats["chunks"] == 0     # no batched chunk ran
    src = g.edge_sources()
    assert not np.any(r.members[src] & r.members[g.indices])


# --------------------------------------------------------------------- #
# sharded execution under 4 forced host devices (subprocess lane)
# --------------------------------------------------------------------- #

SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    assert jax.device_count() == 4, jax.device_count()
    import numpy as np
    from repro.core import serve as SV
    from repro.core.graph import Graph
    from repro.graphs.generators import gnm

    def ref_svc():
        # single-device, non-pipelined reference on the same process
        return SV.MWISService(SV.ServeConfig(
            backend="jnp", max_batch=8, devices=1, pipeline=False))

    def assert_same(a, b, tag):
        assert a.ok == b.ok, tag
        assert a.weight == b.weight, tag
        assert np.array_equal(a.members, b.members), tag

    # ragged mixed-cell batch: 10 instances over two cells, not a
    # multiple of the device count; includes the batch-of-1 spill chunk
    gs = [gnm(20 + 3 * i, 40 + 5 * i, seed=i) for i in range(8)]
    gs += [gnm(120, 300, seed=8), gnm(130, 320, seed=9)]
    want = ref_svc().solve_batch(gs)
    svc = SV.MWISService(SV.ServeConfig(backend="jnp", max_batch=8,
                                        devices=4))
    got = svc.solve_batch(gs)
    for a, b in zip(got, want):
        assert_same(a, b, "ragged-mixed")
    s = svc.stats
    assert s["devices"] == 4, s
    assert s["chunks"] > 0 and s["solve_errors"] == 0, s

    # batch of 1 on 4 devices: pads to one instance per device,
    # phantom results discarded
    one = SV.MWISService(SV.ServeConfig(backend="jnp", devices=4))
    assert_same(one.solve_one(gs[0]), want[0], "batch-of-1")

    # poisoned batchmate: the bad request errors, every healthy
    # batchmate stays bit-identical to the single-device reference
    nan_g = Graph(indptr=np.array([0, 1, 2]),
                  indices=np.array([1, 0], np.int32),
                  weights=np.array([np.nan, 1.0]))
    px = SV.MWISService(SV.ServeConfig(backend="jnp", max_batch=8,
                                       devices=4))
    pres = px.solve_batch([gs[0], nan_g, gs[1], gs[2]])
    assert not pres[1].ok and pres[1].reason == "bad_weight"
    for got_r, want_r in zip([pres[0], pres[2], pres[3]], want[:3]):
        assert_same(got_r, want_r, "poisoned")

    # blocked backend (stacked plans shard too)
    want_b = SV.MWISService(SV.ServeConfig(
        backend="blocked", max_batch=4, devices=1,
        pipeline=False)).solve_batch(gs[:4])
    got_b = SV.MWISService(SV.ServeConfig(
        backend="blocked", max_batch=4, devices=4)).solve_batch(gs[:4])
    for a, b in zip(got_b, want_b):
        assert_same(a, b, "blocked")

    # descent="auto" on a 4-device service: staged instances fall back
    # to the single-device per-instance path and match the reference
    cells = SV.serve_cells()
    big = cells[-1]
    dg = gnm(big.L // 2 + 8, big.L + 16, seed=33)
    d_want = SV.MWISService(SV.ServeConfig(
        backend="jnp", descent="auto", descent_min_L=big.L,
        devices=1, pipeline=False)).solve_batch([dg])[0]
    d_svc = SV.MWISService(SV.ServeConfig(
        backend="jnp", descent="auto", descent_min_L=big.L, devices=4))
    d_got = d_svc.solve_batch([dg])[0]
    assert_same(d_got, d_want, "descent-auto")
    assert d_svc.stats["descent_solves"] == 1

    print("SHARDED PARITY OK")
""")


@pytest.mark.slow
def test_sharded_serving_bit_identical_to_single_device():
    """The tentpole invariant: batch-axis sharding over a 4-device serve
    mesh (+ the host pipeline) is bit-identical per instance to the
    single-device path — across ragged/mixed/poisoned batches, both
    backends, and the descent fallback."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src"
    )
    env.pop("XLA_FLAGS", None)          # the script forces its own
    r = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT], capture_output=True,
        text=True, env=env, timeout=1800,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARDED PARITY OK" in r.stdout
