"""Paper technique × GNN substrate: kernelize a graph with DisReduA, then
train GraphSAGE with fanout sampling on the reduced graph — the integration
point described in DESIGN.md §5 (reduce-before-train as a pipeline stage).

    PYTHONPATH=src python examples/gnn_on_reduced_graph.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    import jax
    import jax.numpy as jnp

    from repro.core import distributed as D, partition as part
    from repro.core.graph import from_edge_list
    from repro.graphs import generators as gen
    from repro.graphs.sampler import sample_fanout
    from repro.models import common as MC
    from repro.models.gnn import graphsage as SAGE
    from repro.train import optimizer as opt

    # 1. instance + distributed kernelization
    g = gen.rgg2d(3000, avg_deg=8, seed=0)
    pg = part.partition_graph(g, 8, window_cap=16)
    state, prob, _ = D.disredu(pg, D.DisReduConfig(mode="async"))
    status = np.asarray(state.status)
    is_local = np.asarray(prob.is_local)
    gids = np.asarray(prob.aux.gid)
    alive = np.zeros(g.n, dtype=bool)
    alive[gids[(status == 0) & is_local]] = True
    print(f"input n={g.n}, reduced kernel n={alive.sum()}")

    # 2. induced reduced graph + sampler
    sub, old_ids = g.induced_subgraph(alive)
    rng = np.random.default_rng(0)
    cfg = SAGE.GraphSAGEConfig(d_feat=16, d_hidden=32, n_classes=4,
                               sample_sizes=(5, 3))
    params = MC.init_params(SAGE.param_specs(cfg), jax.random.key(0))
    ostate = opt.adamw_init(params)
    ocfg = opt.AdamWConfig(lr=1e-2)
    feats = rng.normal(size=(sub.n, 16)).astype(np.float32)
    labels = (feats[:, :4].argmax(-1)).astype(np.int32)  # learnable labels

    @jax.jit
    def step(params, ostate, batch):
        loss, grads = jax.value_and_grad(
            lambda p: SAGE.loss_fn(p, batch, cfg)
        )(params)
        params, ostate = opt.adamw_update(grads, ostate, params, ocfg)
        return loss, params, ostate

    # 3. minibatch training on sampled subgraphs of the KERNEL
    n_sub, e_sub = 400, 1600
    losses = []
    for it in range(30):
        seeds = rng.choice(sub.n, size=32, replace=False)
        s = sample_fanout(sub, seeds, cfg.sample_sizes, rng=rng,
                          pad_nodes=n_sub, pad_edges=e_sub)
        ids = np.where(s.node_ids >= 0, s.node_ids, 0)
        batch = dict(
            node_feat=jnp.asarray(feats[ids]),
            row=jnp.asarray(s.row), col=jnp.asarray(s.col),
            labels=jnp.asarray(labels[ids]),
            label_mask=jnp.asarray(
                (np.arange(n_sub) < s.n_seeds).astype(np.float32)
            ),
        )
        loss, params, ostate = step(params, ostate, batch)
        losses.append(float(loss))
        if it % 10 == 0:
            print(f"iter {it:3d} loss {losses[-1]:.4f}")
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
