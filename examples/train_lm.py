"""Train a reduced-config LM (~10M params) with the full substrate:
deterministic data pipeline, AdamW, checkpoint/restart supervisor.

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""

import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.data.pipeline import LMBatchSpec, lm_batch
    from repro.distributed.checkpoint import CheckpointManager
    from repro.distributed.fault import TrainSupervisor
    from repro.models import common as MC, transformer as T
    from repro.train import optimizer as opt

    cfg = T.TransformerConfig(
        name="lm-10m", n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
        d_head=64, d_ff=1024, vocab=4096, attn_chunk=64, loss_chunks=2,
        local_window=32, global_every=2,  # exercise the hybrid mask too
    )
    print(f"{cfg.name}: {cfg.n_params() / 1e6:.1f}M params")
    params = MC.init_params(T.param_specs(cfg), jax.random.key(0))
    ostate = opt.adamw_init(params)
    ocfg = opt.AdamWConfig(lr=3e-4)
    bspec = LMBatchSpec(args.batch, args.seq, cfg.vocab)

    @jax.jit
    def step_fn(params, ostate, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, batch, cfg)
        )(params)
        params, ostate = opt.adamw_update(grads, ostate, params, ocfg)
        return loss, params, ostate

    sup = TrainSupervisor(CheckpointManager(args.ckpt, keep=2), save_every=25)
    state = {"params": params, "opt": ostate}
    losses = []

    def one(state, step):
        batch = {k: jnp.asarray(v) for k, v in lm_batch(bspec, step).items()}
        loss, p2, o2 = step_fn(state["params"], state["opt"], batch)
        losses.append(float(loss))
        if step % 10 == 0:
            print(f"step {step:4d} loss {float(loss):.4f}", flush=True)
        return {"params": p2, "opt": o2}

    t0 = time.time()
    sup.run(state, one, args.steps, state_template=state)
    print(f"{args.steps} steps in {time.time() - t0:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training must reduce the loss"


if __name__ == "__main__":
    main()
