"""Quickstart: distributed MWIS reduction + reduce-and-peel in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import distributed as D, partition as part, solvers as S
from repro.graphs import generators as gen

# 1. an instance: random hyperbolic-ish graph, uniform weights in [1, 200]
g = gen.rhg_like(5000, avg_deg=8, seed=0)
print(f"graph: n={g.n} m={g.m}")

# 2. partition over p=8 PEs with ghost halos (the paper's machine model)
pg = part.partition_graph(g, p=8, window_cap=16)

# 3. DisReduA: asynchronous distributed reductions to the global fixpoint
state, prob, rounds = D.disredu(pg, D.DisReduConfig(mode="async"))
nv, ne = D.kernel_stats(pg, state)
print(f"DisReduA: {rounds} rounds, kernel |V'|/|V|={nv / g.n:.4f} "
      f"|E'|/|E|={ne / max(g.m, 1):.4f}")

# 4. full reduce-and-peel solver (RnPA) + verification
members, _ = S.solve(pg, "rnp", D.DisReduConfig(mode="async"))
assert g.is_independent_set(members)
print(f"RnPA solution: weight={g.set_weight(members)} size={members.sum()}")
