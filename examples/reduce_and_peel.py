"""End-to-end driver: the paper's full pipeline on all three weak-scaling
graph families, with quality/impact comparison against the sequential
HtWIS-style baseline (Table 7.1 / 7.2 / C.4 at laptop scale).

    PYTHONPATH=src python examples/reduce_and_peel.py [--n 4000] [--p 8]
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--p", type=int, default=8)
    args = ap.parse_args()

    from repro.core import distributed as D, partition as part, solvers as S
    from repro.core import sequential as seq
    from repro.graphs import generators as gen

    print(f"{'family':6s} {'algo':6s} {'weight':>10s} {'quality':>8s} "
          f"{'V`/V':>7s} {'time':>7s}")
    for fam in ("gnm", "rgg", "rhg"):
        g = gen.FAMILIES[fam](args.n, seed=0)
        t0 = time.time()
        w_seq, _ = seq.solve_reduce_and_peel(g)
        t_seq = time.time() - t0
        print(f"{fam:6s} {'seq':6s} {w_seq:10d} {'1.000':>8s} "
              f"{'-':>7s} {t_seq:6.2f}s")
        pg = part.partition_graph(g, args.p, window_cap=16)
        state, prob, _ = D.disredu(pg, D.DisReduConfig(mode='async'))
        nv, _ = D.kernel_stats(pg, state)
        for algo in ("greedy", "rg", "rnp"):
            pg2 = part.partition_graph(g, args.p, window_cap=16)
            t0 = time.time()
            members, _ = S.solve(pg2, algo, D.DisReduConfig(mode="async"))
            dt = time.time() - t0
            assert g.is_independent_set(members)
            w = g.set_weight(members)
            print(f"{fam:6s} {algo:6s} {w:10d} {w / max(w_seq, 1):8.4f} "
                  f"{nv / g.n:7.4f} {dt:6.2f}s")


if __name__ == "__main__":
    main()
