"""jnp oracle for wedge_intersect (materializes the [E, D, D] compare)."""

from __future__ import annotations

import jax.numpy as jnp


def wedge_intersect_ref(wu, wv, awu, actu):
    match = (wu[:, :, None] == wv[:, None, :]).any(-1) & (actu == 1)
    c = (awu * match).sum(-1).astype(jnp.int32)
    k = match.sum(-1).astype(jnp.int32)
    return c, k


def common_neighbor_stats_ref(window, weights, active, row, col):
    """End-to-end jnp path: gather windows and mask weights by the match
    directly — no separate masked-weight/activity [E, D] operands."""
    wu = window[row]
    match = (wu[:, :, None] == window[col][:, None, :]).any(-1) & active[wu]
    c = jnp.where(match, weights[wu], 0).sum(-1).astype(jnp.int32)
    k = match.sum(-1).astype(jnp.int32)
    return c, k
