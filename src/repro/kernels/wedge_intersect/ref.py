"""jnp oracle for wedge_intersect (materializes the [E, D, D] compare)."""

from __future__ import annotations

import jax.numpy as jnp


def wedge_intersect_ref(wu, wv, awu, actu):
    match = (wu[:, :, None] == wv[:, None, :]).any(-1) & (actu == 1)
    c = (awu * match).sum(-1).astype(jnp.int32)
    k = match.sum(-1).astype(jnp.int32)
    return c, k
