"""Public API: gather windows per edge, dispatch pallas/jnp."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import interpret_mode, use_pallas
from repro.kernels.wedge_intersect.kernel import wedge_intersect
from repro.kernels.wedge_intersect.ref import wedge_intersect_ref


def common_neighbor_stats(
    window: jax.Array,   # [V, D] capped neighbor lists (nil padded)
    weights: jax.Array,  # [V] current weights
    active: jax.Array,   # [V] bool
    row: jax.Array,      # [E]
    col: jax.Array,      # [E]
    *,
    force_pallas: bool | None = None,
):
    """(C[e], K[e]) = weighted/active common-neighborhood per edge.

    Entries are drawn from W(row); membership is tested against W(col), so
    the result is the capped lower bound the single-edge rules require.
    """
    wu = window[row]
    wv = window[col]
    ent_act = active[wu]
    awu = jnp.where(ent_act, weights[wu], 0).astype(jnp.int32)
    actu = ent_act.astype(jnp.int32)
    enable = use_pallas() if force_pallas is None else force_pallas
    if enable:
        return wedge_intersect(
            wu, wv, awu, actu, interpret=interpret_mode()
        )
    return wedge_intersect_ref(wu, wv, awu, actu)
