"""Public API: capped-window machinery (per-edge intersections + per-vertex
activity/clique bitmasks), dispatching pallas/jnp.

The [V, D] window layout is the home of everything capped-neighborhood:

  * :func:`common_neighbor_stats` — weighted/active window intersection per
    edge (single-edge rules),
  * :func:`window_active_bits` / :func:`window_clique_ok` — the vectorized
    window activity + clique predicates.  These are the FRESH-status forms
    used by rule *applications* (and by the engine's jnp backend); the
    aggregate engine's blocked/pallas backends compute the same bits through
    the fused edge pass instead (static window-position payloads in the
    SegPlan — see ``repro.core.engine``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import interpret_mode, use_pallas
from repro.kernels.wedge_intersect.kernel import wedge_intersect
from repro.kernels.wedge_intersect.ref import common_neighbor_stats_ref


def common_neighbor_stats(
    window: jax.Array,   # [V, D] capped neighbor lists (nil padded)
    weights: jax.Array,  # [V] current weights
    active: jax.Array,   # [V] bool
    row: jax.Array,      # [E]
    col: jax.Array,      # [E]
    *,
    force_pallas: bool | None = None,
):
    """(C[e], K[e]) = weighted/active common-neighborhood per edge.

    Entries are drawn from W(row); membership is tested against W(col), so
    the result is the capped lower bound the single-edge rules require.
    The [E, D] gathers happen inside the chosen backend branch: the jnp
    reference path masks weights by the match directly instead of
    materializing separate masked-weight/activity operands first.
    """
    enable = use_pallas() if force_pallas is None else force_pallas
    if enable:
        wu = window[row]
        wv = window[col]
        ent_act = active[wu]
        awu = jnp.where(ent_act, weights[wu], 0).astype(jnp.int32)
        return wedge_intersect(
            wu, wv, awu, ent_act.astype(jnp.int32),
            interpret=interpret_mode(),
        )
    return common_neighbor_stats_ref(window, weights, active, row, col)


def window_active_bits(
    active: jax.Array,   # [V] bool (status == UNDECIDED)
    gid: jax.Array,      # [V] i32 global ids (pad/nil = -1)
    window: jax.Array,   # [V, D] capped neighbor lists
) -> jax.Array:
    """[V] i32 — bit i set iff window[v, i] is an active real vertex.

    Vectorized form of the seed's D-unrolled loop: one [V, D] gather, bits
    are disjoint per position so the OR is a plain sum."""
    D = window.shape[1]
    ent_ok = active[window] & (gid[window] >= 0)               # [V, D]
    shifts = jnp.arange(D, dtype=jnp.int32)[None, :]
    return (ent_ok.astype(jnp.int32) << shifts).sum(axis=1)


def window_clique_ok(
    act_bits: jax.Array,      # [V] i32 window activity bits
    win_adj_bits: jax.Array,  # [V, D] i32 static pairwise adjacency bits
) -> jax.Array:
    """[V] bool — do the *active* window entries form a clique?

    Exact when win_complete (window = full static neighbor list); the caller
    must gate on win_complete.  Ghost pairs have no stored edge, so ≥2
    active ghost neighbors naturally fail — matching "a clique in G_i
    contains at most one ghost"."""
    D = win_adj_bits.shape[1]
    shifts = jnp.arange(D, dtype=jnp.int32)[None, :]
    active_i = ((act_bits[:, None] >> shifts) & 1) == 1        # [V, D]
    need = act_bits[:, None] & ~(jnp.int32(1) << shifts)
    bad = active_i & ((need & ~win_adj_bits) != 0)
    return ~bad.any(axis=1)
