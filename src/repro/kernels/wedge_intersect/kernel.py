r"""Per-edge common-neighborhood kernel — the single-edge rules' hot spot.

For every directed edge (u, v) with capped neighbor windows W(u), W(v)
(sorted, nil-padded), computes

    C[e] = Σ_{x ∈ W(u) ∩ W(v)} active(x) · w(x)     (weighted intersection)
    K[e] = |{x ∈ W(u) ∩ W(v) : active(x)}|          (common count)

C feeds Distributed Basic Single-Edge (ω(N(u)\N(v)) = S(u) − C) and K the
clique tests.  Fusing the [D × D] membership compare into VMEM avoids
materializing an [E, D, D] boolean tensor in HBM — the dominant memory
traffic of the jnp formulation.

Grid = edge tiles of E_BLK; per step VMEM holds six [E_BLK, D] operands and
the [E_BLK, D, D] compare lives only in registers/VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wedge_kernel(wu_ref, wv_ref, awu_ref, actu_ref, out_c_ref, out_k_ref):
    wu = wu_ref[...][0]          # [E_BLK, D] window of u (entry ids)
    wv = wv_ref[...][0]          # [E_BLK, D] window of v
    awu = awu_ref[...][0]        # [E_BLK, D] active-masked weights of W(u)
    actu = actu_ref[...][0]      # [E_BLK, D] activity of W(u) entries (i32)
    match = (wu[:, :, None] == wv[:, None, :]).any(-1)   # [E_BLK, D]
    match &= actu == 1
    out_c_ref[...] = (awu * match).sum(-1, keepdims=True)[None].astype(
        out_c_ref.dtype
    )
    out_k_ref[...] = match.sum(-1, keepdims=True)[None].astype(
        out_k_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("e_blk", "interpret"))
def wedge_intersect(
    wu: jax.Array,    # [E, D] int32 — window entries of edge source
    wv: jax.Array,    # [E, D] int32 — window entries of edge target
    awu: jax.Array,   # [E, D] int32 — active weights of wu entries
    actu: jax.Array,  # [E, D] int32 — 1 iff wu entry active (and not nil)
    *,
    e_blk: int = 256,
    interpret: bool = False,
):
    E, D = wu.shape
    n_blocks = (E + e_blk - 1) // e_blk
    pad = n_blocks * e_blk - E

    def pad0(x):
        return jnp.pad(x, ((0, pad), (0, 0))) if pad else x

    wu, wv, awu, actu = map(pad0, (wu, wv, awu, actu))
    # nil-padding trick: padded wu entries are masked by actu == 0.
    c, k = pl.pallas_call(
        _wedge_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, e_blk, D), lambda i: (i, 0, 0))
            for _ in range(4)
        ],
        out_specs=[
            pl.BlockSpec((1, e_blk, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, e_blk, 1), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, e_blk, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, e_blk, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        wu.reshape(n_blocks, e_blk, D), wv.reshape(n_blocks, e_blk, D),
        awu.reshape(n_blocks, e_blk, D), actu.reshape(n_blocks, e_blk, D),
    )
    return c.reshape(-1)[:E], k.reshape(-1)[:E]
