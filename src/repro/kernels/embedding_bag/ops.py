"""Public EmbeddingBag API with pallas/jnp dispatch."""

from __future__ import annotations

import jax

from repro.kernels import interpret_mode, use_pallas
from repro.kernels.embedding_bag.kernel import embedding_bag_fused
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def embedding_bag(table: jax.Array, idx: jax.Array, wgt: jax.Array,
                  *, force_pallas: bool | None = None) -> jax.Array:
    enable = use_pallas() if force_pallas is None else force_pallas
    if enable:
        return embedding_bag_fused(
            table, idx, wgt, interpret=interpret_mode()
        )
    return embedding_bag_ref(table, idx, wgt)
