"""jnp oracle: gather + weighted sum (the manual EmbeddingBag)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(table: jax.Array, idx: jax.Array,
                      wgt: jax.Array) -> jax.Array:
    rows = table[idx]                       # [B, K, D]
    return (rows.astype(jnp.float32)
            * wgt[..., None].astype(jnp.float32)).sum(1).astype(table.dtype)
