"""Fused EmbeddingBag kernel — DLRM's lookup hot path.

out[b] = Σ_k weight[b, k] · table[idx[b, k]]   (sum-mode bag)

JAX has no native EmbeddingBag; the jnp form is gather → multiply →
segment-sum, three HBM round-trips of the [B·K, dim] gathered matrix.  The
kernel fuses them: bags are tiled to [B_BLK, dim] output tiles; the table
stays in HBM (ANY memory space) and rows are DMA'd on demand with
``pl.load`` dynamic slices, accumulating in a VMEM tile.  dim = 128 is one
lane tile — MXU/VPU aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bag_kernel(idx_ref, wgt_ref, table_ref, out_ref, *, k_bag: int):
    b_blk = out_ref.shape[1]

    def body(b, _):
        def inner(j, acc):
            row = idx_ref[0, b, j]
            vec = pl.load(
                table_ref, (pl.dslice(row, 1), slice(None))
            )[0].astype(jnp.float32)
            return acc + vec * wgt_ref[0, b, j].astype(jnp.float32)

        acc = jax.lax.fori_loop(
            0, k_bag, inner,
            jnp.zeros((out_ref.shape[2],), jnp.float32),
        )
        out_ref[0, b, :] = acc.astype(out_ref.dtype)
        return _

    jax.lax.fori_loop(0, b_blk, body, 0)


@functools.partial(jax.jit, static_argnames=("b_blk", "interpret"))
def embedding_bag_fused(
    table: jax.Array,   # [V, D]
    idx: jax.Array,     # [B, K] int32
    wgt: jax.Array,     # [B, K] f32 per-sample weights
    *,
    b_blk: int = 64,
    interpret: bool = False,
) -> jax.Array:
    B, K = idx.shape
    V, D = table.shape
    nb = (B + b_blk - 1) // b_blk
    pad = nb * b_blk - B
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        wgt = jnp.pad(wgt, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_bag_kernel, k_bag=K),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, b_blk, K), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b_blk, K), lambda i: (i, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),   # table stays in HBM
        ],
        out_specs=pl.BlockSpec((1, b_blk, D), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, b_blk, D), table.dtype),
        interpret=interpret,
    )(idx.reshape(nb, b_blk, K), wgt.reshape(nb, b_blk, K), table)
    return out.reshape(nb * b_blk, D)[:B]
