"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three files:

  <name>/kernel.py - pl.pallas_call + explicit BlockSpec VMEM tiling
  <name>/ops.py    - jit'd public wrapper (host packing, fallback dispatch)
  <name>/ref.py    - pure-jnp oracle, used by tests and as the CPU path

On this CPU container kernels execute under ``interpret=True`` (tests);
the dry-run lowers the jnp reference path (``use_pallas() == False``).
On a real TPU deployment set REPRO_USE_PALLAS=1.
"""

import os


def use_pallas() -> bool:
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1"


def interpret_mode() -> bool:
    import jax

    return jax.default_backend() != "tpu"
