"""Pure-jnp oracle for the blocked segment-sum kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_blocked_ref(data: jax.Array, lrow: jax.Array, *,
                            r_blk: int) -> jax.Array:
    n_blocks, e_blk, d = data.shape

    def one(db, lb):
        return jax.ops.segment_sum(db, lb, num_segments=r_blk + 1)[:r_blk]

    return jax.vmap(one)(data, lrow)


def segment_sum_ref(data: jax.Array, seg: jax.Array, n: int) -> jax.Array:
    """Plain CSR/COO segment sum (canonical semantics)."""
    return jax.ops.segment_sum(data, seg, num_segments=n)


def segment_or_ref(
    data: jax.Array,   # [E, Do] non-negative ints < 2**nbits
    seg: jax.Array,    # [E] segment ids
    num_segments: int,
    *,
    nbits: int,
    indices_are_sorted: bool = False,
) -> jax.Array:
    """Per-segment bitwise OR (jax.ops has no segment_or): decompose into
    ``nbits`` 0/1 bitplanes, segment-sum them, repack with count > 0.
    Exact for any edge order (counting is associative)."""
    E, Do = data.shape
    shifts = jnp.arange(nbits, dtype=data.dtype)
    planes = ((data[:, :, None] >> shifts) & 1).reshape(E, Do * nbits)
    cnt = jax.ops.segment_sum(
        planes, seg, num_segments=num_segments,
        indices_are_sorted=indices_are_sorted,
    ).reshape(num_segments, Do, nbits)
    return ((cnt > 0).astype(data.dtype) << shifts).sum(axis=-1)


def segment_fused_blocked_ref(
    data_sum: jax.Array | None,
    data_max: jax.Array | None,
    data_min: jax.Array | None,
    lrow: jax.Array,
    *,
    r_blk: int,
    data_or: jax.Array | None = None,
    or_nbits: int = 16,
):
    """Oracle for the fused sum/max/min/or kernel: per-block jax.ops
    reductions (segment r_blk collects the padding lanes and is sliced
    off)."""

    def blocked(op, data):
        if data is None:
            return None
        return jax.vmap(
            lambda db, lb: op(db, lb, num_segments=r_blk + 1)[:r_blk]
        )(data, lrow)

    def seg_or(db, lb):
        return segment_or_ref(
            db, lb, num_segments=r_blk + 1, nbits=or_nbits
        )[:r_blk]

    return (
        blocked(jax.ops.segment_sum, data_sum),
        blocked(jax.ops.segment_max, data_max),
        blocked(jax.ops.segment_min, data_min),
        jax.vmap(seg_or)(data_or, lrow) if data_or is not None else None,
    )
