"""Pure-jnp oracle for the blocked segment-sum kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_blocked_ref(data: jax.Array, lrow: jax.Array, *,
                            r_blk: int) -> jax.Array:
    n_blocks, e_blk, d = data.shape

    def one(db, lb):
        return jax.ops.segment_sum(db, lb, num_segments=r_blk + 1)[:r_blk]

    return jax.vmap(one)(data, lrow)


def segment_sum_ref(data: jax.Array, seg: jax.Array, n: int) -> jax.Array:
    """Plain CSR/COO segment sum (canonical semantics)."""
    return jax.ops.segment_sum(data, seg, num_segments=n)


def segment_fused_blocked_ref(
    data_sum: jax.Array | None,
    data_max: jax.Array | None,
    data_min: jax.Array | None,
    lrow: jax.Array,
    *,
    r_blk: int,
):
    """Oracle for the fused sum/max/min kernel: per-block jax.ops reductions
    (segment r_blk collects the padding lanes and is sliced off)."""

    def blocked(op, data):
        if data is None:
            return None
        return jax.vmap(
            lambda db, lb: op(db, lb, num_segments=r_blk + 1)[:r_blk]
        )(data, lrow)

    return (
        blocked(jax.ops.segment_sum, data_sum),
        blocked(jax.ops.segment_max, data_max),
        blocked(jax.ops.segment_min, data_min),
    )
