"""Pure-jnp oracle for the blocked segment-sum kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_blocked_ref(data: jax.Array, lrow: jax.Array, *,
                            r_blk: int) -> jax.Array:
    n_blocks, e_blk, d = data.shape

    def one(db, lb):
        return jax.ops.segment_sum(db, lb, num_segments=r_blk + 1)[:r_blk]

    return jax.vmap(one)(data, lrow)


def segment_sum_ref(data: jax.Array, seg: jax.Array, n: int) -> jax.Array:
    """Plain CSR/COO segment sum (canonical semantics)."""
    return jax.ops.segment_sum(data, seg, num_segments=n)
