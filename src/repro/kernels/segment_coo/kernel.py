"""Row-blocked segment-reduction kernels (the SpMM/message-passing primitive).

Layout: the host packs row-sorted COO edges into ``n_blocks`` row blocks of
``R_BLK`` output rows each; every block's edge range is padded to a fixed
``E_BLK`` budget (blocked-ELL).  Grid = (n_blocks,).

Per grid step, VMEM holds:
  data  [E_BLK, D]   gathered edge payloads,
  lrow  [E_BLK, 1]   row index *within* the block (R_BLK for padding),
  out   [R_BLK, D]   accumulator tile.

TPU adaptation: the scatter-accumulate is expressed as a one-hot matmul
(``onehot[lrow] @ data``) so it runs on the MXU instead of serialized
dynamic-update-slices — the standard TPU trick for small-radix scatters.
D should be lane-aligned (×128) and R_BLK sublane-aligned (×8) for full
MXU utilization.

Two entry points:

  * ``segment_sum_blocked``   — the original sum-only kernel (float payloads;
    message passing / embedding reductions),
  * ``segment_fused_blocked`` — fused multi-payload sum + max + min +
    bitwise-OR in ONE pass over the packed edge blocks.  This is the
    aggregate-engine hot path (:mod:`repro.core.engine`): one sweep of the
    MWIS reduction rules needs neighborhood sums (S, deg), maxes (M,
    argmax-id) AND the capped-window activity/clique bitmasks over the same
    masked edge list, so reading the blocked payloads once and producing all
    reductions amortizes the HBM traffic that dominates this memory-bound
    op.  Sums use the one-hot MXU matmul; max/min use a static
    ``R_BLK``-unrolled masked VPU reduction (max has no matmul form).
    Bitwise-OR payloads are decomposed into ``or_nbits`` 0/1 bitplanes and
    pushed through the SAME one-hot matmul (OR == "count per bit > 0"), then
    repacked — so the OR columns ride the MXU too.  Integer payloads are
    exact (addition over int32 is associative), so results are bit-identical
    to ``jax.ops.segment_{sum,max,min}`` / a per-segment ``np.bitwise_or``
    regardless of edge order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _seg_kernel(data_ref, lrow_ref, out_ref, *, r_blk: int):
    data = data_ref[0]                         # [E_BLK, D]
    lrow = lrow_ref[0][:, 0]                   # [E_BLK]
    onehot = (
        lrow[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, r_blk), 1)
    ).astype(data.dtype)                       # [E_BLK, R_BLK]
    out_ref[0] = jax.lax.dot_general(
        onehot, data,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("r_blk", "interpret"))
def segment_sum_blocked(
    data: jax.Array,    # [n_blocks, E_BLK, D]
    lrow: jax.Array,    # [n_blocks, E_BLK] int32 (R_BLK = padding)
    *,
    r_blk: int,
    interpret: bool = False,
) -> jax.Array:
    """Returns [n_blocks, R_BLK, D]; caller reshapes to [n_rows, D]."""
    n_blocks, e_blk, d = data.shape
    # widen the padding row into an extra one-hot column? no: padding rows
    # (lrow == R_BLK) match no iota column and contribute nowhere.
    out = pl.pallas_call(
        functools.partial(_seg_kernel, r_blk=r_blk),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, e_blk, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, e_blk, 1), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, r_blk, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, r_blk, d), data.dtype),
        interpret=interpret,
    )(data, lrow[..., None])
    return out


# --------------------------------------------------------------------- #
# fused multi-payload sum/max/min/or
# --------------------------------------------------------------------- #
def _identity(dtype, kind: str):
    """Reduction identities matching jax.ops.segment_* empty-segment init."""
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        return {"max": info.min, "min": info.max}[kind]
    return {"max": -jnp.inf, "min": jnp.inf}[kind]


def _seg_fused_kernel(*refs, r_blk: int, or_nbits: int, has_sum: bool,
                      has_max: bool, has_min: bool, has_or: bool):
    refs = list(refs)
    dsum = refs.pop(0)[0] if has_sum else None      # [E_BLK, Ds]
    dmax = refs.pop(0)[0] if has_max else None      # [E_BLK, Dm]
    dmin = refs.pop(0)[0] if has_min else None      # [E_BLK, Dn]
    dor = refs.pop(0)[0] if has_or else None        # [E_BLK, Do]
    lrow = refs.pop(0)[0][:, 0]                     # [E_BLK]
    onehot = (
        lrow[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, r_blk), 1)
    )                                               # [E_BLK, R_BLK] bool

    def onehot_matmul(data, acc):
        return jax.lax.dot_general(
            onehot.astype(data.dtype), data,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=acc,
        )

    if has_sum:
        osum_ref = refs.pop(0)
        acc = jnp.int32 if jnp.issubdtype(dsum.dtype, jnp.integer) \
            else jnp.float32
        osum_ref[0] = onehot_matmul(dsum, acc).astype(osum_ref.dtype)
    # max/min have no matmul form: unroll the (small, static) R_BLK axis and
    # reduce each output row's masked payload slice on the VPU.
    if has_max:
        omax_ref = refs.pop(0)
        ident = _identity(dmax.dtype, "max")
        omax_ref[0] = jnp.stack(
            [jnp.max(jnp.where(onehot[:, r : r + 1], dmax, ident), axis=0)
             for r in range(r_blk)], axis=0,
        )
    if has_min:
        omin_ref = refs.pop(0)
        ident = _identity(dmin.dtype, "min")
        omin_ref[0] = jnp.stack(
            [jnp.min(jnp.where(onehot[:, r : r + 1], dmin, ident), axis=0)
             for r in range(r_blk)], axis=0,
        )
    # bitwise OR: unpack each column into or_nbits 0/1 planes and reuse the
    # one-hot matmul (OR over a segment == per-bit count > 0), then repack.
    if has_or:
        oor_ref = refs.pop(0)
        n_or = dor.shape[1]
        shifts = jax.lax.broadcasted_iota(jnp.int32, (1, or_nbits), 1)
        planes = jnp.concatenate(
            [(dor[:, c : c + 1] >> shifts) & 1 for c in range(n_or)],
            axis=1,
        )                                           # [E_BLK, Do * W] 0/1
        counts = onehot_matmul(planes, jnp.int32)   # [R_BLK, Do * W]
        oor_ref[0] = jnp.stack(
            [((counts[:, c * or_nbits : (c + 1) * or_nbits] > 0)
              .astype(jnp.int32) << shifts[0][None, :]).sum(axis=1)
             for c in range(n_or)], axis=1,
        ).astype(oor_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("r_blk", "or_nbits", "interpret")
)
def segment_fused_blocked(
    data_sum: jax.Array | None,   # [n_blocks, E_BLK, Ds] or None
    data_max: jax.Array | None,   # [n_blocks, E_BLK, Dm] or None
    data_min: jax.Array | None,   # [n_blocks, E_BLK, Dn] or None
    lrow: jax.Array,              # [n_blocks, E_BLK] int32 (R_BLK = padding)
    *,
    r_blk: int,
    data_or: jax.Array | None = None,  # [n_blocks, E_BLK, Do] i32, values
                                       # in [0, 2**or_nbits)
    or_nbits: int = 16,
    interpret: bool = False,
):
    """One pass over the packed blocks; returns (sum, max, min, or) outputs
    of shape [n_blocks, R_BLK, D*] (None for absent payload groups)."""
    if not 0 < or_nbits < 32:
        raise ValueError(f"or_nbits must be in (0, 32), got {or_nbits}")
    payloads = [p for p in (data_sum, data_max, data_min, data_or)
                if p is not None]
    if not payloads:
        raise ValueError("segment_fused_blocked needs at least one payload")
    n_blocks, e_blk = payloads[0].shape[:2]
    in_specs, args, out_specs, out_shapes = [], [], [], []
    for p in payloads:
        in_specs.append(pl.BlockSpec((1, e_blk, p.shape[2]),
                                     lambda i: (i, 0, 0)))
        args.append(p)
        out_specs.append(pl.BlockSpec((1, r_blk, p.shape[2]),
                                      lambda i: (i, 0, 0)))
        out_shapes.append(
            jax.ShapeDtypeStruct((n_blocks, r_blk, p.shape[2]), p.dtype)
        )
    in_specs.append(pl.BlockSpec((1, e_blk, 1), lambda i: (i, 0, 0)))
    args.append(lrow[..., None])
    outs = pl.pallas_call(
        functools.partial(
            _seg_fused_kernel, r_blk=r_blk, or_nbits=or_nbits,
            has_sum=data_sum is not None, has_max=data_max is not None,
            has_min=data_min is not None, has_or=data_or is not None,
        ),
        grid=(n_blocks,),
        in_specs=in_specs,
        out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
        out_shape=out_shapes if len(out_shapes) > 1 else out_shapes[0],
        interpret=interpret,
    )(*args)
    outs = list(outs) if isinstance(outs, (list, tuple)) else [outs]
    res = []
    for p in (data_sum, data_max, data_min, data_or):
        res.append(outs.pop(0) if p is not None else None)
    return tuple(res)
