"""Row-blocked segment-sum kernel (the SpMM/message-passing primitive).

Layout: the host packs row-sorted COO edges into ``n_blocks`` row blocks of
``R_BLK`` output rows each; every block's edge range is padded to a fixed
``E_BLK`` budget (blocked-ELL).  Grid = (n_blocks,).

Per grid step, VMEM holds:
  data  [E_BLK, D]   gathered edge payloads,
  lrow  [E_BLK, 1]   row index *within* the block (R_BLK for padding),
  out   [R_BLK, D]   accumulator tile.

TPU adaptation: the scatter-accumulate is expressed as a one-hot matmul
(``onehot[lrow] @ data``) so it runs on the MXU instead of serialized
dynamic-update-slices — the standard TPU trick for small-radix scatters.
D should be lane-aligned (×128) and R_BLK sublane-aligned (×8) for full
MXU utilization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _seg_kernel(data_ref, lrow_ref, out_ref, *, r_blk: int):
    data = data_ref[0]                         # [E_BLK, D]
    lrow = lrow_ref[0][:, 0]                   # [E_BLK]
    onehot = (
        lrow[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, r_blk), 1)
    ).astype(data.dtype)                       # [E_BLK, R_BLK]
    out_ref[0] = jax.lax.dot_general(
        onehot, data,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("r_blk", "interpret"))
def segment_sum_blocked(
    data: jax.Array,    # [n_blocks, E_BLK, D]
    lrow: jax.Array,    # [n_blocks, E_BLK] int32 (R_BLK = padding)
    *,
    r_blk: int,
    interpret: bool = False,
) -> jax.Array:
    """Returns [n_blocks, R_BLK, D]; caller reshapes to [n_rows, D]."""
    n_blocks, e_blk, d = data.shape
    # widen the padding row into an extra one-hot column? no: padding rows
    # (lrow == R_BLK) match no iota column and contribute nowhere.
    out = pl.pallas_call(
        functools.partial(_seg_kernel, r_blk=r_blk),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, e_blk, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, e_blk, 1), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, r_blk, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, r_blk, d), data.dtype),
        interpret=interpret,
    )(data, lrow[..., None])
    return out
