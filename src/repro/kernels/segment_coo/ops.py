"""Public segment-sum API with host-side CSR→blocked-ELL packing and
pallas/jnp dispatch."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import interpret_mode, use_pallas
from repro.kernels.segment_coo.kernel import segment_sum_blocked
from repro.kernels.segment_coo.ref import segment_sum_blocked_ref


def pack_blocks(
    row: np.ndarray, n_rows: int, *, r_blk: int = 8,
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Host packing: row-sorted edge ids → (edge_perm [n_blocks, E_BLK],
    lrow [n_blocks, E_BLK]).  edge_perm indexes the original edge array;
    padding slots point at edge 0 with lrow = r_blk (ignored)."""
    order = np.argsort(row, kind="stable")
    rs = row[order]
    n_blocks = (n_rows + r_blk - 1) // r_blk
    blk_of_edge = rs // r_blk
    counts = np.bincount(blk_of_edge, minlength=n_blocks)
    e_blk = max(int(counts.max(initial=1)), 1)
    edge_perm = np.zeros((n_blocks, e_blk), dtype=np.int64)
    lrow = np.full((n_blocks, e_blk), r_blk, dtype=np.int32)
    starts = np.zeros(n_blocks + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    for b in range(n_blocks):
        sl = slice(starts[b], starts[b + 1])
        k = starts[b + 1] - starts[b]
        edge_perm[b, :k] = order[sl]
        lrow[b, :k] = rs[sl] - b * r_blk
    return edge_perm, lrow, e_blk


def segment_sum_coo(
    data: jax.Array,        # [E, D] edge payloads (original edge order)
    edge_perm: jax.Array,   # [n_blocks, E_BLK] from pack_blocks
    lrow: jax.Array,        # [n_blocks, E_BLK]
    n_rows: int,
    *,
    r_blk: int = 8,
    force_pallas: bool | None = None,
) -> jax.Array:
    """Blocked segment sum; returns [n_rows, D]."""
    n_blocks = edge_perm.shape[0]
    blocked = data[edge_perm.reshape(-1)].reshape(
        n_blocks, edge_perm.shape[1], data.shape[-1]
    )
    enable = use_pallas() if force_pallas is None else force_pallas
    if enable:
        out = segment_sum_blocked(
            blocked, lrow, r_blk=r_blk, interpret=interpret_mode()
        )
    else:
        out = segment_sum_blocked_ref(blocked, lrow, r_blk=r_blk)
    return out.reshape(n_blocks * r_blk, -1)[:n_rows]
