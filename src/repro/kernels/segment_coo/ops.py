"""Public segment-reduction API with host-side CSR→blocked-ELL packing and
pallas/jnp dispatch."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import interpret_mode, use_pallas
from repro.kernels.segment_coo.kernel import (
    segment_fused_blocked, segment_sum_blocked,
)
from repro.kernels.segment_coo.ref import (
    segment_fused_blocked_ref, segment_sum_blocked_ref,
)


def pack_blocks(
    row: np.ndarray, n_rows: int, *, r_blk: int = 8, e_blk_multiple: int = 1,
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Host packing: row-sorted edge ids → (edge_perm [n_blocks, E_BLK],
    lrow [n_blocks, E_BLK]).  edge_perm indexes the original edge array;
    padding slots point at edge 0 with lrow = r_blk (ignored) — so the edge
    array must be non-empty (the partitioned graphs always pad E ≥ 1).
    ``e_blk_multiple`` rounds the edge budget up (sublane alignment)."""
    order = np.argsort(row, kind="stable")
    rs = row[order]
    n_blocks = (n_rows + r_blk - 1) // r_blk
    blk_of_edge = rs // r_blk
    counts = np.bincount(blk_of_edge, minlength=n_blocks)
    e_blk = max(int(counts.max(initial=1)), 1)
    e_blk = ((e_blk + e_blk_multiple - 1) // e_blk_multiple) * e_blk_multiple
    edge_perm = np.zeros((n_blocks, e_blk), dtype=np.int64)
    lrow = np.full((n_blocks, e_blk), r_blk, dtype=np.int32)
    starts = np.zeros(n_blocks + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    for b in range(n_blocks):
        sl = slice(starts[b], starts[b + 1])
        k = starts[b + 1] - starts[b]
        edge_perm[b, :k] = order[sl]
        lrow[b, :k] = rs[sl] - b * r_blk
    return edge_perm, lrow, e_blk


def pack_blocks_stacked(
    rows: np.ndarray, n_rows: int, *, r_blk: int = 8, e_blk_multiple: int = 1,
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Stacked packing for the shard_map path: rows is [p, E]; every PE is
    packed against the same n_rows and padded to a SHARED E_BLK (max over
    PEs) so the per-PE plan arrays stack into one [p, n_blocks, E_BLK]
    mesh-sharded input."""
    p = rows.shape[0]
    packed = [
        pack_blocks(rows[i], n_rows, r_blk=r_blk,
                    e_blk_multiple=e_blk_multiple)
        for i in range(p)
    ]
    e_blk = max(pb[2] for pb in packed)
    n_blocks = packed[0][0].shape[0]
    edge_perm = np.zeros((p, n_blocks, e_blk), dtype=np.int64)
    lrow = np.full((p, n_blocks, e_blk), r_blk, dtype=np.int32)
    for i, (perm_i, lrow_i, eb_i) in enumerate(packed):
        edge_perm[i, :, :eb_i] = perm_i
        lrow[i, :, :eb_i] = lrow_i
    return edge_perm, lrow, e_blk


def segment_sum_coo(
    data: jax.Array,        # [E, D] edge payloads (original edge order)
    edge_perm: jax.Array,   # [n_blocks, E_BLK] from pack_blocks
    lrow: jax.Array,        # [n_blocks, E_BLK]
    n_rows: int,
    *,
    r_blk: int = 8,
    force_pallas: bool | None = None,
) -> jax.Array:
    """Blocked segment sum; returns [n_rows, D]."""
    n_blocks = edge_perm.shape[0]
    blocked = data[edge_perm.reshape(-1)].reshape(
        n_blocks, edge_perm.shape[1], data.shape[-1]
    )
    enable = use_pallas() if force_pallas is None else force_pallas
    if enable:
        out = segment_sum_blocked(
            blocked, lrow, r_blk=r_blk, interpret=interpret_mode()
        )
    else:
        out = segment_sum_blocked_ref(blocked, lrow, r_blk=r_blk)
    return out.reshape(n_blocks * r_blk, -1)[:n_rows]


def segment_fused_coo(
    edge_perm: jax.Array,   # [n_blocks, E_BLK] from pack_blocks
    lrow: jax.Array,        # [n_blocks, E_BLK]
    n_rows: int,
    *,
    data_sum: jax.Array | None = None,   # [E, Ds] edge payloads to sum
    data_max: jax.Array | None = None,   # [E, Dm] edge payloads to max
    data_min: jax.Array | None = None,   # [E, Dn] edge payloads to min
    data_or: jax.Array | None = None,    # [E, Do] edge payloads to bitwise-OR
    or_nbits: int = 16,                  # bit width of the OR payloads
    r_blk: int = 8,
    force_pallas: bool | None = None,
):
    """Fused blocked segment sum+max+min+or over one packed edge list;
    returns a (sum, max, min, or) tuple of [n_rows, D*] arrays (None where
    the payload group is absent).  All payload groups share the single
    gather of the blocked edge permutation — the engine's
    one-pass-per-sweep contract."""
    if all(d is None for d in (data_sum, data_max, data_min, data_or)):
        raise ValueError("segment_fused_coo needs at least one payload")
    n_blocks, e_blk = edge_perm.shape

    def gather(data):
        if data is None:
            return None
        return data[edge_perm.reshape(-1)].reshape(
            n_blocks, e_blk, data.shape[-1]
        )

    bsum, bmax, bmin, bor = (
        gather(data_sum), gather(data_max), gather(data_min), gather(data_or)
    )
    enable = use_pallas() if force_pallas is None else force_pallas
    if enable:
        outs = segment_fused_blocked(
            bsum, bmax, bmin, lrow, data_or=bor, or_nbits=or_nbits,
            r_blk=r_blk, interpret=interpret_mode(),
        )
    else:
        outs = segment_fused_blocked_ref(
            bsum, bmax, bmin, lrow, data_or=bor, or_nbits=or_nbits,
            r_blk=r_blk,
        )
    return tuple(
        o.reshape(n_blocks * r_blk, -1)[:n_rows] if o is not None else None
        for o in outs
    )
