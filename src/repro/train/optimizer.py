"""Optimizers: AdamW (default) and Adafactor (memory-lean for the biggest
archs).  Pure pytree transforms; optimizer state inherits parameter
shardings under pjit (ZeRO-style: 2D-sharded params ⇒ 2D-sharded moments).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0


def adamw_init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    grads: Any, state: AdamWState, params: Any, cfg: AdamWConfig
) -> Tuple[Any, AdamWState]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - cfg.lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(td, [o[0] for o in out])
    new_m = jax.tree.unflatten(td, [o[1] for o in out])
    new_v = jax.tree.unflatten(td, [o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)


# --------------------------------------------------------------------- #
# Adafactor (factored second moment — O(n+m) state for [n, m] weights)
# --------------------------------------------------------------------- #
class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any   # row statistics (or full v for <2D params)
    vc: Any   # col statistics (zeros for <2D params)


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-4
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0


def adafactor_init(params: Any) -> AdafactorState:
    def rows(p):
        if p.ndim >= 2:
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def cols(p):
        if p.ndim >= 2:
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        vr=jax.tree.map(rows, params),
        vc=jax.tree.map(cols, params),
    )


def adafactor_update(
    grads: Any, state: AdafactorState, params: Any, cfg: AdafactorConfig
) -> Tuple[Any, AdafactorState]:
    step = state.step + 1
    beta = 1.0 - step.astype(jnp.float32) ** (-cfg.decay)

    def upd(g, vr, vc, p):
        g = g.astype(jnp.float32)
        g2 = g * g + cfg.eps
        if p.ndim >= 2:
            vr2 = beta * vr + (1 - beta) * g2.mean(axis=-1)
            vc2 = beta * vc + (1 - beta) * g2.mean(axis=-2)
            denom = (
                vr2[..., :, None] * vc2[..., None, :]
                / jnp.maximum(vr2.mean(axis=-1)[..., None, None], cfg.eps)
            )
            u = g * jax.lax.rsqrt(jnp.maximum(denom, cfg.eps))
        else:
            vr2 = beta * vr + (1 - beta) * g2
            vc2 = vc
            u = g * jax.lax.rsqrt(jnp.maximum(vr2, cfg.eps))
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        p2 = p.astype(jnp.float32) - cfg.lr * u
        return p2.astype(p.dtype), vr2, vc2

    flat_p, td = jax.tree.flatten(params)
    out = [
        upd(g, vr, vc, p)
        for g, vr, vc, p in zip(
            jax.tree.leaves(grads), jax.tree.leaves(state.vr),
            jax.tree.leaves(state.vc), flat_p,
        )
    ]
    new_p = jax.tree.unflatten(td, [o[0] for o in out])
    new_vr = jax.tree.unflatten(td, [o[1] for o in out])
    new_vc = jax.tree.unflatten(td, [o[2] for o in out])
    return new_p, AdafactorState(step=step, vr=new_vr, vc=new_vc)


OPTIMIZERS: Dict[str, Tuple[Callable, Callable, Any]] = {
    "adamw": (adamw_init, adamw_update, AdamWConfig()),
    "adafactor": (adafactor_init, adafactor_update, AdafactorConfig()),
}
