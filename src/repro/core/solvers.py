"""Distributed MWIS solvers (§6): GS/GA, RGS/RGA, RnPS/RnPA.

  * greedy (GS/GA)          — distributed weighted Luby: a vertex joins the
    solution iff its (weight, gid) is lexicographically maximal over its
    active neighborhood; border synchronized every round; PE-rank/id
    tie-breaking.  Deterministic == sequential priority greedy
    (`sequential.solve_greedy` is the oracle).
  * reduce-and-greedy (RGS/RGA) — DisRedu{S,A} to the global fixpoint, then
    greedy on the kernel.
  * reduce-and-peel (RnPS/RnPA) — loop { reduce to fixpoint; every PE peels
    its locally worst vertex argmax ω(N(v)) − ω(v) } until empty (HtWIS
    criterion, one peel per PE per round as in the paper).

All algorithms are expressed once over abstract collectives and instantiated
for the union (single-device simulation) and shard_map (production) paths.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as E
from repro.core import exchange as X
from repro.core import rules as R
from repro.core.distributed import (
    DisReduConfig, UnionProblem, _unpack_per_pe, build_union_problem,
    shard_map_arrays, shard_map_compat,
)
from repro.core.local_reduce import local_reduce
from repro.core.partition import PartitionedGraph

UNDECIDED, INCLUDED, EXCLUDED, FOLDED = 0, 1, 2, 3
I32_MIN = jnp.iinfo(jnp.int32).min


class Ctx(NamedTuple):
    """Abstract SPMD context: exchange + global-any + per-PE peel."""

    exchange: Callable  # state -> (state, changed)
    gany: Callable      # bool scalar -> bool scalar (global OR)
    peel: Callable      # (state, score [V]) -> state  (one peel per PE)


# --------------------------------------------------------------------- #
# algorithm bodies (layout-agnostic)
# --------------------------------------------------------------------- #
def _reduce_to_fixpoint(state, aux, ctx: Ctx, cfg: DisReduConfig,
                        plan=None):
    def body(carry):
        state, rounds, _ = carry
        snap_s, snap_w = state.status, state.w
        state = local_reduce(
            state, aux, heavy_k=cfg.heavy_k, use_heavy=cfg.use_heavy,
            max_sweeps=cfg.sweeps_per_round, schedule=cfg.schedule,
            backend=cfg.backend, plan=plan,
        )
        state, _ = ctx.exchange(state)
        changed = ctx.gany(
            (state.status != snap_s).any() | (state.w != snap_w).any()
        )
        return state, rounds + 1, changed

    def cond(carry):
        _, rounds, changed = carry
        return changed & (rounds < cfg.max_rounds)

    state, rounds, _ = jax.lax.while_loop(
        cond, body, (state, jnp.zeros((), jnp.int32), jnp.ones((), bool))
    )
    return state, rounds


def greedy_step(state, aux, *, backend: str = "jnp", plan=None):
    """One weighted-Luby round (no exchange): include every local active
    vertex no active neighbor beats.

    The seed's two reductions (max neighbor weight + min gid among the
    argmaxes) collapse into ONE lexicographic beat test per edge — v wins
    iff no neighbor u has (w[u], -gid[u]) lexicographically above
    (w[v], -gid[v]) — so a greedy round costs a single pass through the
    aggregate backend.  Gids are unique, hence this equals the seed's
    (w > mw) | (w == mw & gid < mg) winner set bit for bit, which is the
    ``sequential.solve_greedy`` oracle semantics.
    """
    V = aux.gid.shape[0]
    active = state.status == UNDECIDED
    eact = active[aux.row] & active[aux.col]
    wc, wr = state.w[aux.col], state.w[aux.row]
    beat_e = eact & (
        (wc > wr) | ((wc == wr) & (aux.gid[aux.col] < aux.gid[aux.row]))
    )
    _, beaten, _, _ = E.aggregate(
        aux.row, V, data_max=beat_e.astype(jnp.int32),
        backend=backend, plan=plan,
    )
    win = aux.is_local & active & (beaten <= 0)
    return R._apply_include(state, aux, eact, win)


def _greedy_rounds(state, aux, ctx: Ctx, max_rounds: int = 100_000,
                   *, backend: str = "jnp", plan=None):
    """Weighted-Luby rounds until no vertex is UNDECIDED anywhere."""

    def body(carry):
        state, rounds, _ = carry
        state = greedy_step(state, aux, backend=backend, plan=plan)
        state, _ = ctx.exchange(state)
        remaining = ctx.gany((aux.is_local & (state.status == UNDECIDED)).any())
        return state, rounds + 1, remaining

    def cond(carry):
        _, rounds, remaining = carry
        return remaining & (rounds < max_rounds)

    remaining0 = ctx.gany((aux.is_local & (state.status == UNDECIDED)).any())
    state, _, _ = jax.lax.while_loop(
        cond, body, (state, jnp.zeros((), jnp.int32), remaining0)
    )
    return state


def peel_score(state, aux, *, backend: str = "jnp", plan=None):
    """[V] HtWIS peel score ω(N(v)) − ω(v) for local active vertices
    (I32_MIN elsewhere), through the aggregate backend."""
    V = aux.gid.shape[0]
    active = state.status == UNDECIDED
    eact = active[aux.row] & active[aux.col]
    aw = jnp.where(active, state.w, 0)
    s, _, _, _ = E.aggregate(
        aux.row, V, data_sum=jnp.where(eact, aw[aux.col], 0),
        backend=backend, plan=plan,
    )
    return jnp.where(aux.is_local & active, s - state.w, I32_MIN)


def _rnp_loop(state, aux, ctx: Ctx, cfg: DisReduConfig,
              max_peels: int = 1_000_000, plan=None):
    """reduce → peel-one-per-PE → repeat until globally empty (§6)."""

    def body(carry):
        state, it, _ = carry
        state, _ = _reduce_to_fixpoint(state, aux, ctx, cfg, plan=plan)
        score = peel_score(state, aux, backend=cfg.backend, plan=plan)
        state = ctx.peel(state, score)
        remaining = ctx.gany((aux.is_local & (state.status == UNDECIDED)).any())
        return state, it + 1, remaining

    def cond(carry):
        _, it, remaining = carry
        return remaining & (it < max_peels)

    remaining0 = ctx.gany((aux.is_local & (state.status == UNDECIDED)).any())
    state, _, _ = jax.lax.while_loop(
        cond, body, (state, jnp.zeros((), jnp.int32), remaining0)
    )
    return state


def run_algorithm(state, aux, ctx: Ctx, cfg: DisReduConfig, algo: str,
                  plan=None):
    """algo ∈ {reduce, greedy, rg, rnp} → final state (all local decided for
    solver algos; kernel remains for 'reduce')."""
    if algo == "reduce":
        state, _ = _reduce_to_fixpoint(state, aux, ctx, cfg, plan=plan)
    elif algo == "greedy":
        state = _greedy_rounds(state, aux, ctx, backend=cfg.backend,
                               plan=plan)
    elif algo == "rg":
        state, _ = _reduce_to_fixpoint(state, aux, ctx, cfg, plan=plan)
        state = _greedy_rounds(state, aux, ctx, backend=cfg.backend,
                               plan=plan)
    elif algo == "rnp":
        state = _rnp_loop(state, aux, ctx, cfg, plan=plan)
    else:
        raise ValueError(f"unknown algo {algo!r}")
    return state


# --------------------------------------------------------------------- #
# union instantiation (single-device SPMD simulation)
# --------------------------------------------------------------------- #
def _union_ctx(prob: UnionProblem, backend: str = "jnp") -> Ctx:
    p, V = prob.p, prob.w0.shape[0] // prob.p

    def exch(state):
        return X.exchange_union(
            state, prob.aux, prob.halo, p=p,
            backend=backend, plan=prob.plan,
        )

    def peel(state, score):
        sc = score.reshape(p, V)
        top = jnp.argmax(sc, axis=1)
        has = sc[jnp.arange(p), top] > I32_MIN
        flat = jnp.where(has, top + jnp.arange(p) * V, p * V - 1)
        # excluding the per-PE argmax; nil slot absorbs empty PEs
        status = state.status.at[flat].set(
            jnp.where(has, jnp.int8(EXCLUDED), jnp.int8(EXCLUDED))
        )
        # nil slots are EXCLUDED already, so unconditional set is safe
        return state._replace(status=status)

    return Ctx(exchange=exch, gany=lambda x: x, peel=peel)


def solve_union_arrays(w0, is_local, is_ghost, aux, halo, plan, *, algo,
                       heavy_k, use_heavy, sweeps, max_rounds, p,
                       schedule="cheap", backend="jnp"):
    """Traceable union-path solve body: arrays in, (state, members) out.

    This is the batch-axis seam of the serving layer: every argument is a
    plain array pytree (no host-side build), so ``jax.vmap`` over a leading
    instance axis yields the batched many-instance solver, and the
    single-instance jit below is the same trace with the axis dropped.
    Keyword arguments must be trace-static.
    """
    prob = UnionProblem(w0, is_local, is_ghost, aux, halo, p, 0, plan)
    cfg = DisReduConfig(
        heavy_k=heavy_k, use_heavy=use_heavy,
        mode="sync" if sweeps >= 1_000_000 else "async",
        stale_sweeps=sweeps, max_rounds=max_rounds, schedule=schedule,
        backend=backend,
    )
    ctx = _union_ctx(prob, backend)
    state = R.init_state(w0, is_local, is_ghost)
    state = run_algorithm(state, aux, ctx, cfg, algo, plan=plan)
    members = R.reconstruct_members(state, aux)
    return state, members


_solve_union_jit = functools.partial(
    jax.jit,
    static_argnames=("algo", "heavy_k", "use_heavy", "sweeps", "max_rounds",
                     "p", "schedule", "backend"),
)(solve_union_arrays)


def solve(
    pg: PartitionedGraph,
    algo: str,
    cfg: DisReduConfig = DisReduConfig(),
) -> Tuple[np.ndarray, R.RedState]:
    """Solve MWIS heuristically; returns (global member mask, final state).

    algo: 'greedy' (GS/GA), 'rg' (RGS/RGA), 'rnp' (RnPS/RnPA) — the S/A
    flavour is chosen by cfg.mode ('sync'/'async').
    """
    prob = build_union_problem(pg, cfg.backend, cfg.r_blk)
    state, in_set = _solve_union_jit(
        prob.w0, prob.is_local, prob.is_ghost, prob.aux, prob.halo,
        prob.plan,
        algo=algo, heavy_k=cfg.heavy_k, use_heavy=cfg.use_heavy,
        sweeps=cfg.sweeps_per_round, max_rounds=cfg.max_rounds, p=prob.p,
        schedule=cfg.schedule, backend=cfg.backend,
    )
    members = np.zeros(pg.n_global, dtype=bool)
    sel = np.asarray(in_set) & np.asarray(prob.is_local)
    members[np.asarray(prob.aux.gid)[sel]] = True
    return members, state


# --------------------------------------------------------------------- #
# staged solve with adaptive shape descent (kernel compaction)
# --------------------------------------------------------------------- #
class LadderCell(NamedTuple):
    """One rung of the static shape ladder (serve/descent MWIS_SHAPES
    cells, or ad-hoc test cells).  L/E gate admission; G/B/S floor the
    halo pads (the exact per-PE maxima override them); r_blk picks the
    blocked-ELL row-block height for plans packed at this rung."""

    name: str
    L: int
    E: int
    G: int = 4
    B: int = 4
    S: int = 4
    r_blk: Optional[int] = None


def default_ladder() -> Tuple[LadderCell, ...]:
    """The configured descent ladder: serve cells + descent extensions
    from ``configs.base.MWIS_SHAPES``, ascending."""
    from repro.configs import base as CFG

    cells = []
    for name in CFG.MWIS_DESCENT_LADDER:
        m = CFG.MWIS_SHAPES[name]
        cells.append(LadderCell(
            name=name, L=m["L"], E=m["E"], G=m["G"], B=m["B"], S=m["S"],
            r_blk=m.get("seg_blk", {}).get("r_blk"),
        ))
    return tuple(sorted(cells, key=lambda c: (c.L, c.E)))


class _Frame(NamedTuple):
    """Pre-descent snapshot: the full-shape state (with its fold log) and
    the aux needed to replay reconstruction at that level."""

    state: R.RedState
    aux: R.Aux
    is_local: jax.Array


@functools.partial(
    jax.jit,
    static_argnames=("phase", "iters", "heavy_k", "use_heavy", "sweeps",
                     "p", "schedule", "backend"),
)
def _stage_union_jit(state, is_ghost, aux, halo, plan, *, phase, iters,
                     heavy_k, use_heavy, sweeps, p, schedule, backend):
    """One bounded solver stage on the union layout.

    phase='reduce' — ≤ `iters` DisRedu rounds; returns (state, rounds,
    changed_last) so the host loop can tell fixpoint (changed False) from
    budget exhaustion even at iters=1.
    phase='greedy' — ≤ `iters` weighted-Luby rounds; returns (state,
    rounds, remaining).
    phase='peel'   — exactly one HtWIS peel per PE (no exchange! ghosts
    are stale until the next reduce round's exchange, which is why the
    staged driver never descends right after a peel).

    Resuming a phase across stage boundaries is exact: reduce rounds are
    idempotent at fixpoint, greedy re-evaluates `remaining` from the
    statuses, and the rnp loop body is reduce-to-fixpoint + peel — so
    chunked execution visits bit-identical states to the monolithic
    while_loops in :func:`run_algorithm`.
    """
    prob = UnionProblem(state.w, aux.is_local, is_ghost, aux, halo, p, 0,
                        plan)
    ctx = _union_ctx(prob, backend)
    if phase == "reduce":
        cfg = DisReduConfig(
            heavy_k=heavy_k, use_heavy=use_heavy,
            mode="sync" if sweeps >= 1_000_000 else "async",
            stale_sweeps=sweeps, schedule=schedule, backend=backend,
            max_rounds=iters,
        )

        def body(carry):
            state, rounds, _ = carry
            snap_s, snap_w = state.status, state.w
            state = local_reduce(
                state, aux, heavy_k=cfg.heavy_k, use_heavy=cfg.use_heavy,
                max_sweeps=cfg.sweeps_per_round, schedule=cfg.schedule,
                backend=cfg.backend, plan=plan,
            )
            state, _ = ctx.exchange(state)
            changed = (state.status != snap_s).any() | (state.w != snap_w).any()
            return state, rounds + 1, changed

        def cond(carry):
            _, rounds, changed = carry
            return changed & (rounds < iters)

        return jax.lax.while_loop(
            cond, body, (state, jnp.zeros((), jnp.int32), jnp.ones((), bool))
        )
    if phase == "greedy":
        def body(carry):
            state, rounds, _ = carry
            state = greedy_step(state, aux, backend=backend, plan=plan)
            state, _ = ctx.exchange(state)
            remaining = (aux.is_local & (state.status == UNDECIDED)).any()
            return state, rounds + 1, remaining

        def cond(carry):
            _, rounds, remaining = carry
            return remaining & (rounds < iters)

        remaining0 = (aux.is_local & (state.status == UNDECIDED)).any()
        return jax.lax.while_loop(
            cond, body, (state, jnp.zeros((), jnp.int32), remaining0)
        )
    if phase != "peel":
        raise ValueError(f"unknown stage phase {phase!r}")
    score = peel_score(state, aux, backend=backend, plan=plan)
    state = ctx.peel(state, score)
    remaining = (aux.is_local & (state.status == UNDECIDED)).any()
    return state, jnp.zeros((), jnp.int32), remaining


#: Host-side stitching calls reconstruction once per descent level; jit it
#: (the monolithic path compiles it into the solve program).
_reconstruct_jit = jax.jit(R.reconstruct_members)


def _pick_cell(ladder, need, cur_L, cur_E, factor):
    """Smallest ladder cell the kernel fits that is a real descent
    (hysteresis: cell.L * factor <= current L, never grow E)."""
    for c in sorted(ladder, key=lambda c: (c.L, c.E)):
        if (c.L * max(factor, 1) <= cur_L and c.E <= cur_E
                and c.L >= need["L"] and c.E >= need["E"]):
            return c
    return None


def solve_staged(
    g,
    p: int,
    algo: str,
    cfg: DisReduConfig = DisReduConfig(),
    *,
    ladder=None,
    plan_cache: Optional[E.PlanCache] = None,
    pad_to=None,
    window_cap: int = 16,
    common_cap: int = 4,
    edge_balanced: bool = True,
    ckpt=None,
    resume: bool = False,
    on_descent=None,
    trajectory: bool = False,
    pg: Optional[PartitionedGraph] = None,
) -> Tuple[np.ndarray, dict]:
    """Staged solve with adaptive **shape descent** (kernel compaction).

    Replaces the old two-phase ``solve_compact`` experiment.  The solve
    runs in bounded *stages* (``cfg.descent_every`` rounds each); at every
    post-exchange stage boundary the alive kernel is measured
    (:func:`distributed.kernel_shape`) and, when it fits a smaller rung of
    the static shape `ladder` with hysteresis ``cfg.descent_factor``, the
    partition is *restricted* onto that cell
    (:func:`partition.compact_partition`), re-packed through
    ``engine.plan_for`` (descent plans hit the topology-keyed PlanCache,
    tagged in ``PlanCacheStats.descent_*``), and the solve continues at
    the smaller shape — so late rounds pay for the kernel, not the input.

    Bit-identity: compaction is an exact restriction (preserved ownership,
    window positions, gids), stage chunking visits the same states as the
    monolithic loops, and decisions stitch back through the per-level fold
    logs — members equal :func:`solve` on the same partition, bit for bit
    (for every algo/backend/schedule; descent off ⇒ literally one stage).

    ``ckpt`` (a ``distributed.checkpoint.CheckpointManager``) saves the
    frame stack + current state at every descent boundary; ``resume=True``
    restores the latest boundary and replays the deterministic compaction
    chain host-side before continuing.  ``on_descent(descents, cell_name)``
    is the test/fault seam, called after each committed descent.

    Returns ``(global member mask, stats)`` with stats keys: descents,
    path, kernel_ratio, alive_final, stages (when ``trajectory``).
    """
    import time as _time

    from repro.core import distributed as D
    from repro.core import partition as _part

    ladder = tuple(ladder) if ladder is not None else default_ladder()
    t0 = _time.perf_counter()
    if pg is None:
        pg = _part.partition_graph(
            g, p, edge_balanced=edge_balanced, window_cap=window_cap,
            common_cap=common_cap, pad_to=pad_to,
        )
    n = pg.n_global
    frames: list = []
    path = [dict(cell="input", L=int(pg.L), E=int(pg.E))]
    descents = 0
    stages: list = []
    min_ratio = 1.0
    budget = cfg.max_rounds

    def _r_blk_for(cell) -> Optional[int]:
        if cfg.backend == "jnp":
            return None
        return cell.r_blk if (cell is not None and cell.r_blk) else cfg.r_blk

    def _build(pg_, cell=None, tag=None):
        return build_union_problem(
            pg_, cfg.backend, _r_blk_for(cell), plan_cache, plan_tag=tag,
        )

    prob = _build(pg)
    state = R.init_state(prob.w0, prob.is_local, prob.is_ghost)
    phase = "greedy" if algo == "greedy" else "reduce"

    if resume and ckpt is not None and ckpt.latest_step() is not None:
        man = ckpt.manifest()
        extra = man["extra"]
        tmpl = {
            "state": D.state_template(int(extra["union_v"][-1])),
            "frames": [D.state_template(int(v))
                       for v in extra["union_v"][:-1]],
        }
        tree = ckpt.restore(tmpl)
        by_name = {c.name: c for c in ladder}
        pg_k, prob_k = pg, prob
        for k, fs in enumerate(tree["frames"]):
            fs = R.RedState(*[jnp.asarray(x) for x in fs])
            frames.append(_Frame(state=fs, aux=prob_k.aux,
                                 is_local=prob_k.is_local))
            pg_k = _part.compact_partition(
                pg_k, np.asarray(fs.status), np.asarray(fs.w),
                pad_to=extra["dims"][k],
            )
            prob_k = _build(pg_k, by_name.get(extra["path"][k + 1]["cell"]),
                            tag="descent")
        pg, prob = pg_k, prob_k
        state = R.RedState(*[jnp.asarray(x) for x in tree["state"]])
        phase = extra["phase"]
        budget = int(extra["budget"])
        descents = int(extra["descents"])
        path = list(extra["path"])
        min_ratio = float(extra["min_ratio"])

    def _alive() -> int:
        status = np.asarray(state.status)
        return int(((status == UNDECIDED) & np.asarray(prob.is_local)).sum())

    def _save(cur_phase: str, cur_budget: int) -> None:
        if ckpt is None:
            return
        tree = {"state": state, "frames": [f.state for f in frames]}
        extra = dict(
            kind="solve_staged", phase=cur_phase, budget=int(cur_budget),
            descents=descents, path=path, min_ratio=min_ratio,
            union_v=[int(f.state.w.shape[0]) for f in frames]
                    + [int(state.w.shape[0])],
            dims=[{k: int(path[j + 1][k]) for k in ("L", "E")}
                  | dict(G=int(dmeta["G"]), B=int(dmeta["B"]),
                         S=int(dmeta["S"]))
                  for j, dmeta in enumerate(path[1:])],
        )
        ckpt.save(descents, tree, extra=extra)

    def _run_stage(phase_name: str, iters: int):
        nonlocal state
        t = _time.perf_counter()
        state, rounds, flag = _stage_union_jit(
            state, prob.is_ghost, prob.aux, prob.halo, prob.plan,
            phase=phase_name, iters=int(iters), heavy_k=cfg.heavy_k,
            use_heavy=cfg.use_heavy, sweeps=cfg.sweeps_per_round, p=pg.p,
            schedule=cfg.schedule, backend=cfg.backend,
        )
        jax.block_until_ready(state.status)
        if trajectory:
            stages.append(dict(
                phase=phase_name, shape=path[-1]["cell"], L=int(pg.L),
                rounds=int(rounds), alive=_alive(),
                us=round((_time.perf_counter() - t) * 1e6, 1),
            ))
        return int(rounds), bool(flag)

    def _maybe_descend(cur_phase: str, cur_budget: int) -> None:
        nonlocal pg, prob, state, descents, min_ratio
        if not cfg.descent:
            return
        status = np.asarray(state.status)
        alive = int(((status == UNDECIDED)
                     & np.asarray(prob.is_local)).sum())
        if alive == 0:
            return
        min_ratio = min(min_ratio, alive / max(n, 1))
        need = D.kernel_shape(pg, status)
        cell = _pick_cell(ladder, need, pg.L, pg.E, cfg.descent_factor)
        if cell is None or not D.ghosts_consistent(pg, status):
            return
        frames.append(_Frame(state=state, aux=prob.aux,
                             is_local=prob.is_local))
        pg = _part.compact_partition(
            pg, status, np.asarray(state.w),
            pad_to=dict(L=cell.L, E=cell.E, G=cell.G, B=cell.B, S=cell.S),
        )
        prob = _build(pg, cell, tag="descent")
        state = R.init_state(prob.w0, prob.is_local, prob.is_ghost)
        descents += 1
        path.append(dict(cell=cell.name, L=int(pg.L), E=int(pg.E),
                         G=int(pg.G), B=int(pg.B), S=int(pg.S)))
        _save(cur_phase, cur_budget)
        if on_descent is not None:
            on_descent(descents, cell.name)

    def _reduce_phase(left: int) -> int:
        while left > 0:
            iters = min(cfg.descent_every, left) if cfg.descent else left
            rounds, changed = _run_stage("reduce", iters)
            left -= rounds
            _maybe_descend("reduce", left)
            if not changed:
                break
        return left

    def _greedy_phase() -> None:
        while _alive():
            iters = cfg.descent_every if cfg.descent else 100_000
            _, remaining = _run_stage("greedy", iters)
            _maybe_descend("greedy", 0)
            if not remaining:
                break

    if algo == "reduce":
        if phase == "reduce":
            budget = _reduce_phase(budget)
    elif algo == "greedy":
        _greedy_phase()
    elif algo == "rg":
        if phase == "reduce":
            budget = _reduce_phase(budget)
            phase = "greedy"
        _greedy_phase()
    elif algo == "rnp":
        while _alive():
            _reduce_phase(budget)
            budget = cfg.max_rounds
            if not _alive():
                break
            _run_stage("peel", 1)
    else:
        raise ValueError(f"unknown algo {algo!r}")

    # ---- stitch: reconstruct innermost-out through the frame stack ---- #
    def _members_at(state_, aux_, is_local_) -> np.ndarray:
        in_set = np.asarray(_reconstruct_jit(state_, aux_))
        members = np.zeros(n, dtype=bool)
        sel = in_set & np.asarray(is_local_)
        members[np.asarray(aux_.gid)[sel]] = True
        return members

    members = _members_at(state, prob.aux, prob.is_local)
    for fr in reversed(frames):
        status = np.asarray(fr.state.status).copy()
        gids = np.asarray(fr.aux.gid)
        member_of_gid = np.zeros(n + 1, dtype=bool)
        member_of_gid[:n] = members
        und = status == UNDECIDED
        decided_in = member_of_gid[np.where(gids >= 0, gids, n)] & und
        status[und] = EXCLUDED
        status[decided_in] = INCLUDED
        st2 = fr.state._replace(status=jnp.asarray(status.astype(np.int8)))
        members = _members_at(st2, fr.aux, fr.is_local)

    stats = dict(
        descents=descents, path=path, kernel_ratio=min_ratio,
        alive_final=_alive(), t_total=_time.perf_counter() - t0,
    )
    if trajectory:
        stats["stages"] = stages
    return members, stats


def solver_shard_map_fn(pg: PartitionedGraph, cfg: DisReduConfig, mesh,
                        algo: str, axis: str = "pe"):
    """Build the shard_map'd solver over stacked [p, ...] arrays."""
    from jax.sharding import PartitionSpec as P

    arrs = shard_map_arrays(pg, cfg)
    keys = list(arrs.keys())

    def per_pe(*args):
        aux, halo, plan, a = _unpack_per_pe(pg, keys, args)

        def exch(state):
            return X.exchange_shmap(
                state, aux, halo, axis=axis, method=cfg.exchange,
                backend=cfg.backend, plan=plan,
            )

        def gany(x):
            return jax.lax.psum(x.astype(jnp.int32), axis) > 0

        def peel(state, score):
            top = jnp.argmax(score)
            has = score[top] > I32_MIN
            idx = jnp.where(has, top, score.shape[0] - 1)
            status = state.status.at[idx].set(jnp.int8(EXCLUDED))
            return state._replace(status=status)

        ctx = Ctx(exchange=exch, gany=gany, peel=peel)
        state = R.init_state(a["w0"], a["is_local"], a["is_ghost"])
        state = run_algorithm(state, aux, ctx, cfg, algo, plan=plan)
        members = R.reconstruct_members(state, aux)
        ex = lambda t: t.reshape((1,) + t.shape)
        return (ex(state.w), ex(state.status), ex(members),
                ex(state.offset), ex(state.log_n))

    in_specs = tuple(P(axis) for _ in keys)
    out_specs = (P(axis),) * 5
    fn = shard_map_compat(per_pe, mesh, in_specs, out_specs)

    def run(arrays=None):
        arrays = arrays or {k: jnp.asarray(v) for k, v in arrs.items()}
        return fn(*(arrays[k] for k in keys))

    return run, keys


def sweep_probe_shard_map_fn(pg: PartitionedGraph, cfg: DisReduConfig, mesh,
                             axis: str = "pe"):
    """Loop-free roofline probe: exactly ONE rule sweep + ONE halo exchange
    (+ one heavy-vertex pass).  DisRedu's while-loops have data-dependent
    trip counts, so the honest static roofline unit is per sweep-round —
    cost_analysis of this probe is exact (no hidden loop bodies)."""
    from jax.sharding import PartitionSpec as P

    arrs = shard_map_arrays(pg, cfg)
    keys = list(arrs.keys())

    def per_pe(*args):
        aux, halo, plan, a = _unpack_per_pe(pg, keys, args)
        state = R.init_state(a["w0"], a["is_local"], a["is_ghost"])
        state = E.sweep(
            state, aux, schedule=cfg.schedule, backend=cfg.backend, plan=plan
        )
        if cfg.use_heavy:
            state = R.rule_heavy_vertex(state, aux, cfg.heavy_k)
        state, _ = X.exchange_shmap(
            state, aux, halo, axis=axis, method=cfg.exchange,
            backend=cfg.backend, plan=plan,
        )
        ex = lambda t: t.reshape((1,) + t.shape)
        return ex(state.w), ex(state.status), ex(state.offset)

    fn = shard_map_compat(
        per_pe, mesh, tuple(P(axis) for _ in keys), (P(axis),) * 3
    )

    def run(arrays):
        return fn(*(arrays[k] for k in keys))

    return run, keys
