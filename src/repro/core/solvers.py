"""Distributed MWIS solvers (§6): GS/GA, RGS/RGA, RnPS/RnPA.

  * greedy (GS/GA)          — distributed weighted Luby: a vertex joins the
    solution iff its (weight, gid) is lexicographically maximal over its
    active neighborhood; border synchronized every round; PE-rank/id
    tie-breaking.  Deterministic == sequential priority greedy
    (`sequential.solve_greedy` is the oracle).
  * reduce-and-greedy (RGS/RGA) — DisRedu{S,A} to the global fixpoint, then
    greedy on the kernel.
  * reduce-and-peel (RnPS/RnPA) — loop { reduce to fixpoint; every PE peels
    its locally worst vertex argmax ω(N(v)) − ω(v) } until empty (HtWIS
    criterion, one peel per PE per round as in the paper).

All algorithms are expressed once over abstract collectives and instantiated
for the union (single-device simulation) and shard_map (production) paths.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as E
from repro.core import exchange as X
from repro.core import rules as R
from repro.core.distributed import (
    DisReduConfig, UnionProblem, _unpack_per_pe, build_union_problem,
    shard_map_arrays, shard_map_compat,
)
from repro.core.local_reduce import local_reduce
from repro.core.partition import PartitionedGraph

UNDECIDED, INCLUDED, EXCLUDED, FOLDED = 0, 1, 2, 3
I32_MIN = jnp.iinfo(jnp.int32).min


class Ctx(NamedTuple):
    """Abstract SPMD context: exchange + global-any + per-PE peel."""

    exchange: Callable  # state -> (state, changed)
    gany: Callable      # bool scalar -> bool scalar (global OR)
    peel: Callable      # (state, score [V]) -> state  (one peel per PE)


# --------------------------------------------------------------------- #
# algorithm bodies (layout-agnostic)
# --------------------------------------------------------------------- #
def _reduce_to_fixpoint(state, aux, ctx: Ctx, cfg: DisReduConfig,
                        plan=None):
    def body(carry):
        state, rounds, _ = carry
        snap_s, snap_w = state.status, state.w
        state = local_reduce(
            state, aux, heavy_k=cfg.heavy_k, use_heavy=cfg.use_heavy,
            max_sweeps=cfg.sweeps_per_round, schedule=cfg.schedule,
            backend=cfg.backend, plan=plan,
        )
        state, _ = ctx.exchange(state)
        changed = ctx.gany(
            (state.status != snap_s).any() | (state.w != snap_w).any()
        )
        return state, rounds + 1, changed

    def cond(carry):
        _, rounds, changed = carry
        return changed & (rounds < cfg.max_rounds)

    state, rounds, _ = jax.lax.while_loop(
        cond, body, (state, jnp.zeros((), jnp.int32), jnp.ones((), bool))
    )
    return state, rounds


def greedy_step(state, aux, *, backend: str = "jnp", plan=None):
    """One weighted-Luby round (no exchange): include every local active
    vertex no active neighbor beats.

    The seed's two reductions (max neighbor weight + min gid among the
    argmaxes) collapse into ONE lexicographic beat test per edge — v wins
    iff no neighbor u has (w[u], -gid[u]) lexicographically above
    (w[v], -gid[v]) — so a greedy round costs a single pass through the
    aggregate backend.  Gids are unique, hence this equals the seed's
    (w > mw) | (w == mw & gid < mg) winner set bit for bit, which is the
    ``sequential.solve_greedy`` oracle semantics.
    """
    V = aux.gid.shape[0]
    active = state.status == UNDECIDED
    eact = active[aux.row] & active[aux.col]
    wc, wr = state.w[aux.col], state.w[aux.row]
    beat_e = eact & (
        (wc > wr) | ((wc == wr) & (aux.gid[aux.col] < aux.gid[aux.row]))
    )
    _, beaten, _, _ = E.aggregate(
        aux.row, V, data_max=beat_e.astype(jnp.int32),
        backend=backend, plan=plan,
    )
    win = aux.is_local & active & (beaten <= 0)
    return R._apply_include(state, aux, eact, win)


def _greedy_rounds(state, aux, ctx: Ctx, max_rounds: int = 100_000,
                   *, backend: str = "jnp", plan=None):
    """Weighted-Luby rounds until no vertex is UNDECIDED anywhere."""

    def body(carry):
        state, rounds, _ = carry
        state = greedy_step(state, aux, backend=backend, plan=plan)
        state, _ = ctx.exchange(state)
        remaining = ctx.gany((aux.is_local & (state.status == UNDECIDED)).any())
        return state, rounds + 1, remaining

    def cond(carry):
        _, rounds, remaining = carry
        return remaining & (rounds < max_rounds)

    remaining0 = ctx.gany((aux.is_local & (state.status == UNDECIDED)).any())
    state, _, _ = jax.lax.while_loop(
        cond, body, (state, jnp.zeros((), jnp.int32), remaining0)
    )
    return state


def peel_score(state, aux, *, backend: str = "jnp", plan=None):
    """[V] HtWIS peel score ω(N(v)) − ω(v) for local active vertices
    (I32_MIN elsewhere), through the aggregate backend."""
    V = aux.gid.shape[0]
    active = state.status == UNDECIDED
    eact = active[aux.row] & active[aux.col]
    aw = jnp.where(active, state.w, 0)
    s, _, _, _ = E.aggregate(
        aux.row, V, data_sum=jnp.where(eact, aw[aux.col], 0),
        backend=backend, plan=plan,
    )
    return jnp.where(aux.is_local & active, s - state.w, I32_MIN)


def _rnp_loop(state, aux, ctx: Ctx, cfg: DisReduConfig,
              max_peels: int = 1_000_000, plan=None):
    """reduce → peel-one-per-PE → repeat until globally empty (§6)."""

    def body(carry):
        state, it, _ = carry
        state, _ = _reduce_to_fixpoint(state, aux, ctx, cfg, plan=plan)
        score = peel_score(state, aux, backend=cfg.backend, plan=plan)
        state = ctx.peel(state, score)
        remaining = ctx.gany((aux.is_local & (state.status == UNDECIDED)).any())
        return state, it + 1, remaining

    def cond(carry):
        _, it, remaining = carry
        return remaining & (it < max_peels)

    remaining0 = ctx.gany((aux.is_local & (state.status == UNDECIDED)).any())
    state, _, _ = jax.lax.while_loop(
        cond, body, (state, jnp.zeros((), jnp.int32), remaining0)
    )
    return state


def run_algorithm(state, aux, ctx: Ctx, cfg: DisReduConfig, algo: str,
                  plan=None):
    """algo ∈ {reduce, greedy, rg, rnp} → final state (all local decided for
    solver algos; kernel remains for 'reduce')."""
    if algo == "reduce":
        state, _ = _reduce_to_fixpoint(state, aux, ctx, cfg, plan=plan)
    elif algo == "greedy":
        state = _greedy_rounds(state, aux, ctx, backend=cfg.backend,
                               plan=plan)
    elif algo == "rg":
        state, _ = _reduce_to_fixpoint(state, aux, ctx, cfg, plan=plan)
        state = _greedy_rounds(state, aux, ctx, backend=cfg.backend,
                               plan=plan)
    elif algo == "rnp":
        state = _rnp_loop(state, aux, ctx, cfg, plan=plan)
    else:
        raise ValueError(f"unknown algo {algo!r}")
    return state


# --------------------------------------------------------------------- #
# union instantiation (single-device SPMD simulation)
# --------------------------------------------------------------------- #
def _union_ctx(prob: UnionProblem, backend: str = "jnp") -> Ctx:
    p, V = prob.p, prob.w0.shape[0] // prob.p

    def exch(state):
        return X.exchange_union(
            state, prob.aux, prob.halo, p=p,
            backend=backend, plan=prob.plan,
        )

    def peel(state, score):
        sc = score.reshape(p, V)
        top = jnp.argmax(sc, axis=1)
        has = sc[jnp.arange(p), top] > I32_MIN
        flat = jnp.where(has, top + jnp.arange(p) * V, p * V - 1)
        # excluding the per-PE argmax; nil slot absorbs empty PEs
        status = state.status.at[flat].set(
            jnp.where(has, jnp.int8(EXCLUDED), jnp.int8(EXCLUDED))
        )
        # nil slots are EXCLUDED already, so unconditional set is safe
        return state._replace(status=status)

    return Ctx(exchange=exch, gany=lambda x: x, peel=peel)


def solve_union_arrays(w0, is_local, is_ghost, aux, halo, plan, *, algo,
                       heavy_k, use_heavy, sweeps, max_rounds, p,
                       schedule="cheap", backend="jnp"):
    """Traceable union-path solve body: arrays in, (state, members) out.

    This is the batch-axis seam of the serving layer: every argument is a
    plain array pytree (no host-side build), so ``jax.vmap`` over a leading
    instance axis yields the batched many-instance solver, and the
    single-instance jit below is the same trace with the axis dropped.
    Keyword arguments must be trace-static.
    """
    prob = UnionProblem(w0, is_local, is_ghost, aux, halo, p, 0, plan)
    cfg = DisReduConfig(
        heavy_k=heavy_k, use_heavy=use_heavy,
        mode="sync" if sweeps >= 1_000_000 else "async",
        stale_sweeps=sweeps, max_rounds=max_rounds, schedule=schedule,
        backend=backend,
    )
    ctx = _union_ctx(prob, backend)
    state = R.init_state(w0, is_local, is_ghost)
    state = run_algorithm(state, aux, ctx, cfg, algo, plan=plan)
    members = R.reconstruct_members(state, aux)
    return state, members


_solve_union_jit = functools.partial(
    jax.jit,
    static_argnames=("algo", "heavy_k", "use_heavy", "sweeps", "max_rounds",
                     "p", "schedule", "backend"),
)(solve_union_arrays)


def solve(
    pg: PartitionedGraph,
    algo: str,
    cfg: DisReduConfig = DisReduConfig(),
) -> Tuple[np.ndarray, R.RedState]:
    """Solve MWIS heuristically; returns (global member mask, final state).

    algo: 'greedy' (GS/GA), 'rg' (RGS/RGA), 'rnp' (RnPS/RnPA) — the S/A
    flavour is chosen by cfg.mode ('sync'/'async').
    """
    prob = build_union_problem(pg, cfg.backend, cfg.r_blk)
    state, in_set = _solve_union_jit(
        prob.w0, prob.is_local, prob.is_ghost, prob.aux, prob.halo,
        prob.plan,
        algo=algo, heavy_k=cfg.heavy_k, use_heavy=cfg.use_heavy,
        sweeps=cfg.sweeps_per_round, max_rounds=cfg.max_rounds, p=prob.p,
        schedule=cfg.schedule, backend=cfg.backend,
    )
    members = np.zeros(pg.n_global, dtype=bool)
    sel = np.asarray(in_set) & np.asarray(prob.is_local)
    members[np.asarray(prob.aux.gid)[sel]] = True
    return members, state


# --------------------------------------------------------------------- #
# shard_map instantiation (production / dry-run)
# --------------------------------------------------------------------- #
def solve_compact(
    g,
    p: int,
    algo: str,
    cfg: DisReduConfig = DisReduConfig(),
    *,
    pre_rounds: int = 2,
    window_cap: int = 16,
) -> Tuple[np.ndarray, dict]:
    """Beyond-paper driver (EXPERIMENTS §Perf H3 next-step): kernel
    compaction.

    The paper prunes redundant rule tests with dependency checking; under
    static shapes every sweep still pays for the full padded instance.
    This driver runs `pre_rounds` DisRedu rounds, *extracts the kernel*
    (alive vertices with their current weights), repartitions the much
    smaller residual, solves it with `algo`, and stitches the solution
    back through the phase-1 reconstruction — later sweeps cost ∝ kernel
    size instead of input size.  Exactness is unchanged: the kernel is an
    equivalent instance by the paper's Theorems 4.x.

    Returns (global member mask, stats).
    """
    import time as _time

    from repro.core import partition as _part
    from repro.core.distributed import disredu, kernel_stats

    t0 = _time.time()
    pg = _part.partition_graph(g, p, window_cap=window_cap)
    pre_cfg = DisReduConfig(
        heavy_k=cfg.heavy_k, use_heavy=cfg.use_heavy, mode=cfg.mode,
        stale_sweeps=cfg.stale_sweeps, exchange=cfg.exchange,
        schedule=cfg.schedule, backend=cfg.backend, max_rounds=pre_rounds,
    )
    state, prob, rounds = disredu(pg, pre_cfg)
    nv, ne = kernel_stats(pg, state)
    t_phase1 = _time.time() - t0

    status = np.asarray(state.status)
    w = np.asarray(state.w)
    is_local = np.asarray(prob.is_local)
    gids = np.asarray(prob.aux.gid)

    alive_g = np.zeros(g.n, dtype=bool)
    w_g = np.zeros(g.n, dtype=np.int64)
    sel = (status == UNDECIDED) & is_local
    alive_g[gids[sel]] = True
    w_g[gids[sel]] = w[sel]

    members = np.zeros(g.n, dtype=bool)
    if alive_g.any():
        # induced residual with CURRENT (possibly folded-down) weights
        sub, old_ids = g.induced_subgraph(alive_g)
        sub = type(sub)(indptr=sub.indptr, indices=sub.indices,
                        weights=w_g[old_ids].astype(np.int32))
        pg2 = _part.partition_graph(sub, p, window_cap=window_cap)
        members2, _ = solve(pg2, algo, cfg)
        members[old_ids[members2]] = True

    # stitch back: phase-2 decisions seed the phase-1 reconstruction
    status2 = status.copy()
    member_of_gid = np.zeros(g.n + 1, dtype=bool)
    member_of_gid[:g.n] = members
    und = status == UNDECIDED
    decided_in = member_of_gid[np.where(gids >= 0, gids, g.n)] & und
    status2[und] = EXCLUDED
    status2[decided_in] = INCLUDED
    st2 = state._replace(status=jnp.asarray(status2.astype(np.int8)))
    in_set = np.asarray(R.reconstruct_members(st2, prob.aux))
    out = np.zeros(g.n, dtype=bool)
    keep = in_set & is_local
    out[gids[keep]] = True
    stats = dict(
        pre_rounds=rounds, kernel_v=nv, kernel_e=ne,
        kernel_ratio=nv / max(g.n, 1), t_phase1=t_phase1,
    )
    return out, stats


def solver_shard_map_fn(pg: PartitionedGraph, cfg: DisReduConfig, mesh,
                        algo: str, axis: str = "pe"):
    """Build the shard_map'd solver over stacked [p, ...] arrays."""
    from jax.sharding import PartitionSpec as P

    arrs = shard_map_arrays(pg, cfg)
    keys = list(arrs.keys())

    def per_pe(*args):
        aux, halo, plan, a = _unpack_per_pe(pg, keys, args)

        def exch(state):
            return X.exchange_shmap(
                state, aux, halo, axis=axis, method=cfg.exchange,
                backend=cfg.backend, plan=plan,
            )

        def gany(x):
            return jax.lax.psum(x.astype(jnp.int32), axis) > 0

        def peel(state, score):
            top = jnp.argmax(score)
            has = score[top] > I32_MIN
            idx = jnp.where(has, top, score.shape[0] - 1)
            status = state.status.at[idx].set(jnp.int8(EXCLUDED))
            return state._replace(status=status)

        ctx = Ctx(exchange=exch, gany=gany, peel=peel)
        state = R.init_state(a["w0"], a["is_local"], a["is_ghost"])
        state = run_algorithm(state, aux, ctx, cfg, algo, plan=plan)
        members = R.reconstruct_members(state, aux)
        ex = lambda t: t.reshape((1,) + t.shape)
        return (ex(state.w), ex(state.status), ex(members),
                ex(state.offset), ex(state.log_n))

    in_specs = tuple(P(axis) for _ in keys)
    out_specs = (P(axis),) * 5
    fn = shard_map_compat(per_pe, mesh, in_specs, out_specs)

    def run(arrays=None):
        arrays = arrays or {k: jnp.asarray(v) for k, v in arrs.items()}
        return fn(*(arrays[k] for k in keys))

    return run, keys


def sweep_probe_shard_map_fn(pg: PartitionedGraph, cfg: DisReduConfig, mesh,
                             axis: str = "pe"):
    """Loop-free roofline probe: exactly ONE rule sweep + ONE halo exchange
    (+ one heavy-vertex pass).  DisRedu's while-loops have data-dependent
    trip counts, so the honest static roofline unit is per sweep-round —
    cost_analysis of this probe is exact (no hidden loop bodies)."""
    from jax.sharding import PartitionSpec as P

    arrs = shard_map_arrays(pg, cfg)
    keys = list(arrs.keys())

    def per_pe(*args):
        aux, halo, plan, a = _unpack_per_pe(pg, keys, args)
        state = R.init_state(a["w0"], a["is_local"], a["is_ghost"])
        state = E.sweep(
            state, aux, schedule=cfg.schedule, backend=cfg.backend, plan=plan
        )
        if cfg.use_heavy:
            state = R.rule_heavy_vertex(state, aux, cfg.heavy_k)
        state, _ = X.exchange_shmap(
            state, aux, halo, axis=axis, method=cfg.exchange,
            backend=cfg.backend, plan=plan,
        )
        ex = lambda t: t.reshape((1,) + t.shape)
        return ex(state.w), ex(state.status), ex(state.offset)

    fn = shard_map_compat(
        per_pe, mesh, tuple(P(axis) for _ in keys), (P(axis),) * 3
    )

    def run(arrays):
        return fn(*(arrays[k] for k in keys))

    return run, keys
