"""Exact MWIS on small (sub)graphs via adjacency bitmasks.

Two roles:

1. Host-side oracle (`mwis_exact`) for property tests and for the
   sequential baseline's sub-solver — the stand-in for the paper's use of
   KaMIS wB&R [32] on bounded subproblems (§5.1 caps them at 10 vertices).

2. A fully-vectorised in-JIT variant (`alpha_neighborhood_jnp`, see
   :mod:`repro.core.rules`) used by Distributed Heavy Vertex: exhaustive
   enumeration of the 2^K subsets of a K-capped neighborhood with
   independence checked against a K×K adjacency bitmask.  On TPU this is a
   dense integer workload — ideal for the VPU — instead of the pointer-chasing
   branch-and-reduce a CPU would run.
"""

from __future__ import annotations

import sys
from functools import lru_cache
from typing import List, Tuple

import numpy as np

from repro.core.graph import Graph

sys.setrecursionlimit(100000)


def adjacency_masks(g: Graph) -> List[int]:
    masks = [0] * g.n
    src = g.edge_sources()
    for u, v in zip(src.tolist(), g.indices.tolist()):
        masks[u] |= 1 << v
    return masks


def mwis_exact(g: Graph) -> Tuple[int, np.ndarray]:
    """Exact MWIS weight + one optimal member mask. Exponential; n ≤ ~40."""
    n = g.n
    masks = adjacency_masks(g)
    w = g.weights.astype(np.int64).tolist()

    @lru_cache(maxsize=None)
    def solve(allowed: int) -> int:
        if allowed == 0:
            return 0
        # Pick the lowest-indexed allowed vertex; branch on it.
        v = (allowed & -allowed).bit_length() - 1
        without = solve(allowed & ~(1 << v))
        with_v = w[v] + solve(allowed & ~masks[v] & ~(1 << v))
        return max(without, with_v)

    full = (1 << n) - 1
    best = solve(full)

    # Reconstruct one optimum by re-tracing the DP.
    members = np.zeros(n, dtype=bool)
    allowed = full
    remaining = best
    while allowed:
        v = (allowed & -allowed).bit_length() - 1
        with_v = w[v] + solve(allowed & ~masks[v] & ~(1 << v))
        if with_v == remaining:
            members[v] = True
            remaining -= w[v]
            allowed &= ~masks[v] & ~(1 << v)
        else:
            allowed &= ~(1 << v)
    return int(best), members


def alpha_subset(weights: np.ndarray, adj_bits: np.ndarray) -> int:
    """α of a ≤K-vertex graph given per-vertex adjacency bitmasks (numpy).

    Mirrors the vectorised in-JIT form: enumerate all 2^K subsets, keep
    independent ones, maximise weight.  `adj_bits[i]` has bit j set iff
    vertices i and j are adjacent.
    """
    k = int(weights.shape[0])
    if k == 0:
        return 0
    subsets = np.arange(1 << k, dtype=np.int64)
    sel = ((subsets[:, None] >> np.arange(k)[None, :]) & 1).astype(bool)
    conflict = np.zeros(subsets.shape[0], dtype=bool)
    for i in range(k):
        conflict |= sel[:, i] & ((subsets & int(adj_bits[i])) != 0)
    totals = sel @ weights.astype(np.int64)
    totals[conflict] = -1
    return int(totals.max(initial=0))
