"""Instance validation, canonicalization, and verified outputs.

The serving layer admits instances from untrusted callers, and the paper's
central robustness claim — reductions are *equivalence-preserving*
(α-preservation, §reconstruction) — is only meaningful on a well-formed
input: a symmetric, loop-free CSR graph with non-negative integer weights
(:class:`repro.core.graph.Graph`'s documented contract).  This module is
the admission gate and the post-solve auditor:

  * :func:`canonicalize` — repair what is harmlessly repairable
    (self-loops dropped, duplicate directed edges deduped, asymmetric edge
    lists symmetrized, unsorted rows resorted, integral float weights cast)
    and **reject with a stable reason code** what is not (broken CSR
    structure, out-of-range indices, NaN/±inf weights, negative weights,
    int32 overflow).  Repairs never change the MWIS: a self-loop vertex is
    conventionally never a member, and dedup/symmetrize/sort preserve the
    undirected edge *set*.
  * :func:`verify_result` — the cheap O(n + m) post-solve checker: the
    returned mask is an independent set of the (canonical) instance and
    the reported weight matches a recomputation.  Wired into
    ``MWISService`` behind ``ServeConfig.verify`` (off | sample | full).

Reason codes are part of the service API (``ServeResult.reason``); keep
them stable.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import numpy as np

from repro.core.graph import Graph

I32_MAX = np.iinfo(np.int32).max

# --------------------------------------------------------------------- #
# stable reject/error reason codes (the service API surface)
# --------------------------------------------------------------------- #
REASON_BAD_CSR = "bad_csr"            # indptr/indices structurally broken
REASON_BAD_INDEX = "bad_index"        # edge endpoint out of [0, n)
REASON_BAD_WEIGHT = "bad_weight"      # NaN/inf/non-integral/negative/overflow
REASON_OVERSIZE = "oversize"          # exceeds every serve cell (route to
                                      # repro.core.solvers.solve)
REASON_PACK_FAILED = "pack_failed"    # partition/plan build raised
REASON_BACKEND_FAILED = "backend_failed"  # every backend in the chain raised
REASON_VERIFY_FAILED = "verify_failed"    # post-solve check rejected output

#: Repair tags canonicalize may report (informational, not errors).
REPAIR_SELF_LOOPS = "dropped_self_loops"
REPAIR_DUP_EDGES = "deduped_edges"
REPAIR_SYMMETRIZED = "symmetrized"
REPAIR_RESORTED = "resorted_rows"
REPAIR_WEIGHT_CAST = "cast_weights"


class InvalidInstance(ValueError):
    """Rejected instance; ``reason`` is a stable code, ``detail`` human text."""

    def __init__(self, reason: str, detail: str):
        super().__init__(f"{reason}: {detail}")
        self.reason = reason
        self.detail = detail


class ValidationReport(NamedTuple):
    ok: bool
    reason: Optional[str]        # reject reason code (None when ok)
    detail: str                  # human-readable explanation
    repairs: Tuple[str, ...]     # canonicalizations applied (ok case)


def _reject(reason: str, detail: str) -> Tuple[None, ValidationReport]:
    return None, ValidationReport(False, reason, detail, ())


def residual_weights(w, *, where: str = "residual") -> np.ndarray:
    """Folded weight plane of a mid-solve residual, checked into int32.

    Reduction folds rewrite weights (w(u) -= w(v), weight transfers), so a
    residual extracted mid-solve carries *derived* weights that no input
    gate ever saw.  The old ``solve_compact`` driver gathered them as int64
    and silently ``.astype(np.int32)``-downcast — an overflow there wraps
    negative and corrupts every later beat test.  This is the checked seam:
    any value outside [0, I32_MAX] raises :class:`InvalidInstance` with the
    stable ``bad_weight`` reason instead of wrapping.
    """
    w64 = np.asarray(w).astype(np.int64, copy=False)
    if w64.size:
        lo, hi = int(w64.min()), int(w64.max())
        if lo < 0 or hi > I32_MAX:
            raise InvalidInstance(
                REASON_BAD_WEIGHT,
                f"{where}: folded weights out of int32 range "
                f"(min={lo}, max={hi})")
    return w64.astype(np.int32)


def canonicalize(g: Graph) -> Tuple[Optional[Graph], ValidationReport]:
    """Validate + canonicalize one instance; never raises.

    Returns ``(graph, report)``: on success the graph is the input object
    itself when it was already canonical (identity preserved so topology
    caches keep hitting) or a repaired copy; on rejection the graph is
    ``None`` and ``report.reason`` carries the stable code.
    """
    # -- structure: the three arrays must exist and be 1-D numerics ----- #
    try:
        indptr = np.asarray(g.indptr)
        indices = np.asarray(g.indices)
        weights = np.asarray(g.weights)
    except Exception as e:  # noqa: BLE001 — malformed duck-typed input
        return _reject(REASON_BAD_CSR, f"not array-like: {e}")
    if indptr.ndim != 1 or indices.ndim != 1 or weights.ndim != 1:
        return _reject(REASON_BAD_CSR, "indptr/indices/weights must be 1-D")
    if not np.issubdtype(indptr.dtype, np.integer):
        return _reject(REASON_BAD_CSR, f"indptr dtype {indptr.dtype} not integer")
    n = int(weights.shape[0])

    # -- weights: finite, integral, in [0, int32 max] ------------------- #
    repairs = []
    if np.issubdtype(weights.dtype, np.floating):
        if not np.all(np.isfinite(weights)):
            return _reject(REASON_BAD_WEIGHT, "non-finite (NaN/inf) weights")
        if np.any(weights != np.trunc(weights)):
            return _reject(REASON_BAD_WEIGHT, "non-integral float weights")
        repairs.append(REPAIR_WEIGHT_CAST)
    elif not np.issubdtype(weights.dtype, np.integer):
        return _reject(REASON_BAD_WEIGHT,
                       f"weight dtype {weights.dtype} is not numeric-integral")
    w64 = weights.astype(np.int64, copy=False)
    if n and int(w64.min()) < 0:
        return _reject(REASON_BAD_WEIGHT, "negative weights")
    if n and int(w64.max()) > I32_MAX:
        return _reject(REASON_BAD_WEIGHT, "weights overflow int32")
    if weights.dtype != np.int32:
        if REPAIR_WEIGHT_CAST not in repairs:
            repairs.append(REPAIR_WEIGHT_CAST)
    w32 = w64.astype(np.int32)

    # -- CSR invariants ------------------------------------------------- #
    if indptr.shape[0] != n + 1:
        return _reject(
            REASON_BAD_CSR,
            f"indptr has {indptr.shape[0]} entries for n={n} (want n+1)")
    if indptr.size and (int(indptr[0]) != 0
                        or int(indptr[-1]) != indices.shape[0]):
        return _reject(REASON_BAD_CSR,
                       "indptr[0] != 0 or indptr[-1] != len(indices)")
    if np.any(np.diff(indptr) < 0):
        return _reject(REASON_BAD_CSR, "indptr not monotone")
    if indices.size:
        if not np.issubdtype(indices.dtype, np.integer):
            return _reject(REASON_BAD_INDEX,
                           f"indices dtype {indices.dtype} not integer")
        if int(indices.min()) < 0 or int(indices.max()) >= n:
            return _reject(REASON_BAD_INDEX,
                           f"edge endpoint out of range [0, {n})")

    # -- edge canonicalization: loops, dups, asymmetry, order ----------- #
    src = np.repeat(np.arange(n, dtype=np.int64),
                    np.diff(indptr).astype(np.int64))
    dst = indices.astype(np.int64)
    loops = src == dst
    if np.any(loops):
        repairs.append(REPAIR_SELF_LOOPS)
        src, dst = src[~loops], dst[~loops]
    # undirected edge set: unique (min, max) pairs, re-emitted both ways
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    und = np.unique(np.stack([lo, hi], axis=1), axis=0) if src.size else \
        np.zeros((0, 2), np.int64)
    canon_src = np.concatenate([und[:, 0], und[:, 1]])
    canon_dst = np.concatenate([und[:, 1], und[:, 0]])
    order = np.lexsort((canon_dst, canon_src))
    canon_src, canon_dst = canon_src[order], canon_dst[order]
    dir_pairs = (np.unique(np.stack([src, dst], axis=1), axis=0)
                 if src.size else np.zeros((0, 2), np.int64))
    if dir_pairs.shape[0] != src.shape[0]:
        repairs.append(REPAIR_DUP_EDGES)
    if dir_pairs.shape[0] != canon_src.shape[0]:
        repairs.append(REPAIR_SYMMETRIZED)
    if (REPAIR_DUP_EDGES not in repairs
            and REPAIR_SYMMETRIZED not in repairs
            and not (np.array_equal(canon_src, src)
                     and np.array_equal(canon_dst, dst))):
        repairs.append(REPAIR_RESORTED)

    if not repairs:
        return g, ValidationReport(True, None, "canonical", ())

    counts = np.bincount(canon_src, minlength=n).astype(np.int64)
    new_indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=new_indptr[1:])
    fixed = Graph(indptr=new_indptr, indices=canon_dst.astype(np.int32),
                  weights=w32)
    return fixed, ValidationReport(True, None, "repaired", tuple(repairs))


def validate_instance(g: Graph) -> Graph:
    """:func:`canonicalize` that raises :class:`InvalidInstance` on reject."""
    fixed, report = canonicalize(g)
    if not report.ok:
        raise InvalidInstance(report.reason, report.detail)
    return fixed


# --------------------------------------------------------------------- #
# post-solve output verification
# --------------------------------------------------------------------- #
class VerifyReport(NamedTuple):
    ok: bool
    reason: Optional[str]    # REASON_VERIFY_FAILED when not ok
    detail: str
    weight: int              # recomputed solution weight


def verify_result(
    g: Graph, members: np.ndarray, weight: Optional[int] = None
) -> VerifyReport:
    """Cheap O(n + m) audit of a solver output against its instance.

    Checks that ``members`` is a [n] boolean mask, that it is an
    independent set of ``g`` (no edge with both endpoints selected), and —
    when ``weight`` is given — that the reported weight equals the
    recomputed ``Σ w[members]``.  Never raises; the report is structured
    so the service can degrade per-request.
    """
    m = np.asarray(members)
    if m.shape != (g.n,) or m.dtype != np.bool_:
        return VerifyReport(
            False, REASON_VERIFY_FAILED,
            f"mask shape/dtype {m.shape}/{m.dtype} != ({g.n},)/bool", 0)
    src = g.edge_sources()
    conflicts = int(np.count_nonzero(m[src] & m[g.indices]))
    got = int(g.weights[m].sum(dtype=np.int64))
    if conflicts:
        return VerifyReport(
            False, REASON_VERIFY_FAILED,
            f"{conflicts // 2} edge(s) with both endpoints selected", got)
    if weight is not None and got != int(weight):
        return VerifyReport(
            False, REASON_VERIFY_FAILED,
            f"reported weight {int(weight)} != recomputed {got}", got)
    return VerifyReport(True, None, "verified", got)
