"""Border exchange — §5.2 "Communicating Reduction Progress" in JAX.

Two message types, exactly as the paper defines them:

  (1) weight decrease  — interface weights are re-published so ghost copies
      stay valid upper bounds (Lemma 4.2),
  (2) vertex status    — excluded / proposed-to-include updates, with the
      Lemma 4.4/4.5 rank tie-breaking for conflicting include proposals.

Collective realisations (both produce identical (gw, gs) per ghost):

  * ``allgather`` — every PE publishes its interface *board*; ghosts index
    their owner's board entry.  O(p·B) bytes per PE; simple; the baseline.
  * ``a2a``       — padded per-destination buckets via ``lax.all_to_all``;
    each PE receives only the entries it actually ghosts.  O(p·S) bytes
    with S = max pairwise halo — the bandwidth-optimal variant (§Perf).

Every function exists in two layouts driven by the same `reconcile` core:

  * per-PE layout (inside ``shard_map``; lax collectives), and
  * "union" layout — all PEs stacked into one block-diagonal graph on a
    single device; collectives become array indexing.  This is the CPU test
    / simulation path: it executes the *same SPMD semantics* deterministically
    without needing p host devices.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine as E
from repro.core import rules as R
from repro.core.partition import PartitionedGraph

UNDECIDED, INCLUDED, EXCLUDED, FOLDED = 0, 1, 2, 3


class Halo(NamedTuple):
    """Halo routing (one PE's slice, or stacked [p, ...] for the union)."""

    iface_slots: jax.Array       # [B] local idx of board slots (pad = nil)
    ghost_vertex: jax.Array      # [G] vertex index of each ghost slot
    ghost_owner_pe: jax.Array    # [G] rank owning the ghost (pad = 0)
    ghost_owner_slot: jax.Array  # [G] slot in owner's board (pad = 0)
    ghost_valid: jax.Array       # [G] bool
    send_slot: jax.Array         # [p, S] board slots per destination (pad = B)
    recv_ghost: jax.Array        # [p, S] ghost slot per source (pad = G)


def make_halo(pg: PartitionedGraph, pe: int | None = None) -> Halo:
    """pe=None → stacked [p, ...] halo (union layout uses vertex offsets)."""
    import numpy as np

    L, G, V = pg.L, pg.G, pg.V
    if pe is None:
        off = (np.arange(pg.p, dtype=np.int64) * V)[:, None]
        iface = np.where(
            pg.iface_slots < pg.nil, pg.iface_slots + off, pg.p * V
        )
        gvert = off + L + np.arange(G)[None, :]
        return Halo(
            iface_slots=jnp.asarray(iface, jnp.int32),
            ghost_vertex=jnp.asarray(gvert, jnp.int32),
            ghost_owner_pe=jnp.asarray(
                np.maximum(pg.owner_pe[:, L : L + G], 0), jnp.int32
            ),
            ghost_owner_slot=jnp.asarray(pg.ghost_owner_slot, jnp.int32),
            ghost_valid=jnp.asarray(pg.is_ghost[:, L : L + G]),
            send_slot=jnp.asarray(pg.send_slot, jnp.int32),
            recv_ghost=jnp.asarray(pg.recv_ghost, jnp.int32),
        )
    return Halo(
        iface_slots=jnp.asarray(pg.iface_slots[pe], jnp.int32),
        ghost_vertex=jnp.asarray(L + jnp.arange(G), jnp.int32),
        ghost_owner_pe=jnp.asarray(
            jnp.maximum(jnp.asarray(pg.owner_pe[pe, L : L + G]), 0), jnp.int32
        ),
        ghost_owner_slot=jnp.asarray(pg.ghost_owner_slot[pe], jnp.int32),
        ghost_valid=jnp.asarray(pg.is_ghost[pe, L : L + G]),
        send_slot=jnp.asarray(pg.send_slot[pe], jnp.int32),
        recv_ghost=jnp.asarray(pg.recv_ghost[pe], jnp.int32),
    )


# --------------------------------------------------------------------- #
# reconcile: apply (gw, gs) ghost updates + include-conflict tie-breaking
# --------------------------------------------------------------------- #
def reconcile(
    state: R.RedState,
    aux: R.Aux,
    ghost_vertex: jax.Array,
    ghost_valid: jax.Array,
    gw: jax.Array,
    gs: jax.Array,
    *,
    backend: str = "jnp",
    plan: Optional[E.SegPlan] = None,
) -> Tuple[R.RedState, jax.Array]:
    """Apply board-derived ghost weight/status updates.

    Conflicting include proposals across a cut edge can only be the
    isolated-equal-weight-edge case (Lemma 4.4); both sides deterministically
    keep the endpoint owned by the *smaller* rank (Lemma 4.5).
    Returns (state, changed).

    All conflict reductions are keyed by ``aux.row`` — the sorted segment
    axis the SegPlan packs — so they route through the same blocked pass as
    the rule aggregates.  The partition stores both directions of every
    edge, so the seed's col-keyed existence tests are re-expressed with
    swapped endpoint payloads (identical booleans over a symmetric edge
    set).  ``num_segments`` is the static V everywhere.
    """
    V = state.w.shape[0]
    nilv = V - 1

    # Scatter board values into V-sized arrays (ghost slots only).
    tgt = jnp.where(ghost_valid, ghost_vertex, nilv)
    bw = jnp.full(V, jnp.iinfo(jnp.int32).max, jnp.int32).at[tgt].set(
        jnp.where(ghost_valid, gw, jnp.iinfo(jnp.int32).max)
    )
    bs = jnp.full(V, -1, jnp.int32).at[tgt].set(
        jnp.where(ghost_valid, gs.astype(jnp.int32), -1)
    )

    status = state.status
    rank_r = aux.owner_rank[aux.row]
    rank_c = aux.owner_rank[aux.col]

    # --- include-proposal conflicts over cut edges -------------------- #
    ghost_inc = bs == INCLUDED                       # [V] board says included
    prop_local = (status == INCLUDED) & aux.is_iface
    # (a) local proposal v = row loses iff a proposing ghost neighbor's
    #     owner has the smaller rank
    v_lose_e = (
        prop_local[aux.row] & ghost_inc[aux.col]
        & (aux.gid[aux.col] >= 0) & (rank_c < rank_r)
    )
    # (b) the ghost's proposal u = row loses iff our local proposal has the
    #     smaller rank
    u_lose_e = (
        ghost_inc[aux.row] & prop_local[aux.col]
        & (aux.gid[aux.row] >= 0) & (rank_c < rank_r)
    )
    _, losses, _, _ = E.aggregate(
        aux.row, V,
        data_max=jnp.stack([v_lose_e, u_lose_e], axis=1).astype(jnp.int32),
        backend=backend, plan=plan,
    )
    v_lose = losses[:, 0] > 0
    u_lose = losses[:, 1] > 0
    status = jnp.where(
        v_lose & (status == INCLUDED), jnp.int8(EXCLUDED), status
    )

    # --- ghost status update ------------------------------------------ #
    is_ghost_slot = bs >= 0
    new_ghost = jnp.where(
        (bs == INCLUDED) & ~u_lose,
        jnp.int32(INCLUDED),
        jnp.where(
            (bs == EXCLUDED) | (bs == FOLDED) | ((bs == INCLUDED) & u_lose),
            jnp.int32(EXCLUDED),
            status.astype(jnp.int32),  # owner still UNDECIDED: keep local view
        ),
    )
    status2 = jnp.where(is_ghost_slot, new_ghost.astype(jnp.int8), status)

    # --- weight decrease (owner is authoritative; monotone) ------------ #
    w2 = jnp.where(is_ghost_slot, jnp.minimum(state.w, bw), state.w)

    # --- exclude local active neighbors of newly-included ghosts ------- #
    ginc_now = is_ghost_slot & (status2 == INCLUDED)
    _, hit_m, _, _ = E.aggregate(
        aux.row, V, data_max=ginc_now[aux.col].astype(jnp.int32),
        backend=backend, plan=plan,
    )
    status3 = jnp.where(
        (hit_m > 0) & (status2 == UNDECIDED) & aux.is_local,
        jnp.int8(EXCLUDED), status2,
    )

    changed = (status3 != state.status).any() | (w2 != state.w).any()
    new_state = state._replace(w=w2, status=status3)
    return new_state, changed


# --------------------------------------------------------------------- #
# board construction + the two collective realisations
# --------------------------------------------------------------------- #
def _board(state: R.RedState, iface_slots: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Board values; padded slots index nil → weight 0 / EXCLUDED (ignored
    because padded ghosts are invalid on the receiving side)."""
    bw = state.w[iface_slots]
    bs = state.status[iface_slots]
    return bw, bs


def exchange_shmap(
    state: R.RedState, aux: R.Aux, halo: Halo, *, axis: str = "pe",
    method: str = "allgather",
    backend: str = "jnp", plan: Optional[E.SegPlan] = None,
) -> Tuple[R.RedState, jax.Array]:
    """Per-PE exchange with lax collectives (inside shard_map)."""
    bw, bs = _board(state, halo.iface_slots)
    if method == "allgather":
        boards_w = jax.lax.all_gather(bw, axis)                  # [p, B]
        boards_s = jax.lax.all_gather(bs, axis)
        gw = boards_w[halo.ghost_owner_pe, halo.ghost_owner_slot]
        gs = boards_s[halo.ghost_owner_pe, halo.ghost_owner_slot]
    elif method == "a2a":
        B = bw.shape[0]
        bw_ext = jnp.concatenate([bw, jnp.zeros(1, bw.dtype)])
        bs_ext = jnp.concatenate([bs, jnp.full(1, EXCLUDED, bs.dtype)])
        send_w = bw_ext[halo.send_slot]                          # [p, S]
        send_s = bs_ext[halo.send_slot]
        recv_w = jax.lax.all_to_all(send_w, axis, 0, 0, tiled=True)
        recv_s = jax.lax.all_to_all(send_s, axis, 0, 0, tiled=True)
        G = halo.ghost_vertex.shape[0]
        gw = jnp.zeros(G + 1, jnp.int32).at[halo.recv_ghost.reshape(-1)].set(
            recv_w.reshape(-1)
        )[:G]
        gs = jnp.zeros(G + 1, jnp.int8).at[halo.recv_ghost.reshape(-1)].set(
            recv_s.reshape(-1)
        )[:G]
    else:
        raise ValueError(f"unknown exchange method {method!r}")
    return reconcile(
        state, aux, halo.ghost_vertex, halo.ghost_valid, gw, gs,
        backend=backend, plan=plan,
    )


def union_boards(
    state: R.RedState, halo: Halo
) -> Tuple[jax.Array, jax.Array]:
    """Every PE's published interface board in the union layout.

    Returns ``(boards_w, boards_s)``, both [p, B] — the message each PE
    *would* put on the wire this round.  This is the fault-injection seam:
    :mod:`repro.distributed.fault` snapshots these boards per round and
    feeds delayed/dropped variants back through
    :func:`reconcile_union_boards`, which is exactly a late/lost message
    in the bounded-staleness exchange (§5.4).
    """
    # halo.iface_slots is [p, B] with union indices (pad = p*V, clamped).
    nil_u = state.w.shape[0] - 1
    slots = jnp.minimum(halo.iface_slots, nil_u)
    return state.w[slots], state.status[slots]


def reconcile_union_boards(
    state: R.RedState, aux: R.Aux, halo: Halo,
    boards_w: jax.Array, boards_s: jax.Array, *,
    backend: str = "jnp", plan: Optional[E.SegPlan] = None,
) -> Tuple[R.RedState, jax.Array]:
    """Apply a full [p, B] board set (possibly stale) to the union state."""
    gw = boards_w[halo.ghost_owner_pe, halo.ghost_owner_slot]  # [p, G]
    gs = boards_s[halo.ghost_owner_pe, halo.ghost_owner_slot]
    return reconcile(
        state, aux,
        halo.ghost_vertex.reshape(-1),
        halo.ghost_valid.reshape(-1),
        gw.reshape(-1), gs.reshape(-1),
        backend=backend, plan=plan,
    )


def exchange_union(
    state: R.RedState, aux: R.Aux, halo: Halo, *, p: int,
    backend: str = "jnp", plan: Optional[E.SegPlan] = None,
) -> Tuple[R.RedState, jax.Array]:
    """Union-layout exchange: 'collectives' are plain indexing across the
    stacked [p, ...] halo (single-device simulation of the SPMD program)."""
    boards_w, boards_s = union_boards(state, halo)
    return reconcile_union_boards(
        state, aux, halo, boards_w, boards_s, backend=backend, plan=plan,
    )
