"""Local reduction phase (§5.1): exhaustive fixed-order rule application.

Per PE, rules sweep until no rule fires — the paper restarts from the first
rule after every successful application; our batched equivalent applies all
scheduled cheap families per sweep and only pays for Distributed Heavy
Vertex (the expensive exact-sub-MWIS rule, last in the paper's order too) on
sweeps where the cheap families made no progress.

Which families run, and how their test aggregates are computed, is data:
the `schedule` names an :data:`repro.core.engine.SCHEDULES` entry and the
`backend`/`plan` pair picks the segment-reduction backend (see engine.py).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine as E
from repro.core import rules as R
from repro.core.partition import PartitionedGraph


def make_aux(pg: PartitionedGraph, pe: int | None = None) -> R.Aux:
    """Build the static Aux pytree; pe=None keeps the stacked [p, ...] axis."""
    sl = (slice(None),) if pe is None else (pe,)

    def take(a):
        return jnp.asarray(a[sl])

    return R.Aux(
        row=take(pg.row), col=take(pg.col), gid=take(pg.gid),
        is_local=take(pg.is_local), is_iface=take(pg.is_iface),
        owner_rank=take(pg.owner_pe),
        window=take(pg.window), win_complete=take(pg.win_complete),
        win_adj_bits=take(pg.win_adj_bits), edge_common=take(pg.edge_common),
    )


def local_reduce(
    state: R.RedState,
    aux: R.Aux,
    *,
    heavy_k: int = 8,
    use_heavy: bool = True,
    max_sweeps: int = 10_000,
    schedule: str = "cheap",
    backend: str = "jnp",
    plan: Optional[E.SegPlan] = None,
) -> R.RedState:
    """Run rule sweeps to the local fixpoint (lax.while_loop)."""

    def body(carry):
        state, _ = carry
        state = state._replace(changed=jnp.zeros((), bool))
        state = E.sweep(
            state, aux, schedule=schedule, backend=backend, plan=plan
        )
        if use_heavy:
            state = jax.lax.cond(
                state.changed,
                lambda s: s,
                lambda s: R.rule_heavy_vertex(s, aux, heavy_k),
                state,
            )
        return state, carry[1] + 1

    def cond(carry):
        state, it = carry
        return state.changed & (it < max_sweeps)

    state = state._replace(changed=jnp.ones((), bool))
    state, _ = jax.lax.while_loop(
        cond, body, (state, jnp.zeros((), jnp.int32))
    )
    return state


@functools.partial(
    jax.jit, static_argnames=("heavy_k", "use_heavy", "schedule", "backend")
)
def _reduce_jit(w0, is_local, is_ghost, aux, plan, heavy_k, use_heavy,
                schedule, backend):
    state = R.init_state(w0, is_local, is_ghost)
    return local_reduce(
        state, aux, heavy_k=heavy_k, use_heavy=use_heavy,
        schedule=schedule, backend=backend, plan=plan,
    )


def reduce_single_pe(
    pg: PartitionedGraph, *, heavy_k: int = 8, use_heavy: bool = True,
    schedule: str = "cheap", backend: str = "jnp",
    r_blk: int | None = None,
) -> Tuple[R.RedState, R.Aux]:
    """Single-PE (p must be 1) reduction — the sequential-semantics entry
    point used by tests and as the p=1 baseline of the scaling benches."""
    assert pg.p == 1, "reduce_single_pe expects an unpartitioned graph"
    aux = make_aux(pg, pe=0)
    plan = None if backend == "jnp" else E.build_plan(
        pg.row[0], pg.V, r_blk=r_blk,
        col=pg.col[0], gid=pg.gid[0], window=pg.window[0],
        win_adj_bits=pg.win_adj_bits[0],
    )
    state = _reduce_jit(
        jnp.asarray(pg.w0[0]),
        jnp.asarray(pg.is_local[0]),
        jnp.asarray(pg.is_ghost[0]),
        aux, plan, heavy_k, use_heavy, schedule, backend,
    )
    return state, aux
