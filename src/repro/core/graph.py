"""Vertex-weighted undirected graph container (host side, numpy CSR).

The paper (§3) represents the input as a vertex-weighted directed graph in
adjacency-array format: every undirected edge {u, v} is stored as the two
directed edges (u, v) and (v, u).  This module is the host-side source of
truth from which local (per-PE) subgraphs with ghost halos are carved
(see :mod:`repro.core.partition`).

Weights are non-negative int32 (the paper draws uniform integers from
[1, 200]).  Keeping integer weights makes every rule test exact — no
float-tolerance case analysis in the reduction proofs.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected vertex-weighted graph as symmetric CSR.

    Attributes:
      indptr:  [n+1] int64 — CSR row pointer.
      indices: [2m] int32 — CSR column indices (both edge directions present,
               rows sorted ascending).
      weights: [n] int32 — non-negative vertex weights.
    """

    indptr: np.ndarray
    indices: np.ndarray
    weights: np.ndarray

    # ------------------------------------------------------------------ #
    # Basic accessors
    # ------------------------------------------------------------------ #
    @property
    def n(self) -> int:
        return int(self.weights.shape[0])

    @property
    def num_directed_edges(self) -> int:
        return int(self.indices.shape[0])

    @property
    def m(self) -> int:
        """Number of undirected edges."""
        return self.num_directed_edges // 2

    def degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def edge_sources(self) -> np.ndarray:
        """Expanded CSR row index per directed edge ([2m] int32)."""
        return np.repeat(
            np.arange(self.n, dtype=np.int32), np.diff(self.indptr)
        )

    # ------------------------------------------------------------------ #
    # Validation
    # ------------------------------------------------------------------ #
    def validate(self) -> None:
        n = self.n
        assert self.indptr.shape == (n + 1,)
        assert self.indptr[0] == 0 and self.indptr[-1] == self.indices.shape[0]
        assert np.all(np.diff(self.indptr) >= 0), "indptr must be monotone"
        if self.indices.size:
            assert self.indices.min() >= 0 and self.indices.max() < n
        assert np.all(self.weights >= 0), "weights must be non-negative"
        src = self.edge_sources()
        assert not np.any(src == self.indices), "self loops are not allowed"
        # Symmetry: the multiset of (u, v) equals the multiset of (v, u).
        fwd = np.stack([src, self.indices], axis=1)
        rev = np.stack([self.indices, src], axis=1)
        fwd_sorted = fwd[np.lexsort((fwd[:, 1], fwd[:, 0]))]
        rev_sorted = rev[np.lexsort((rev[:, 1], rev[:, 0]))]
        assert np.array_equal(fwd_sorted, rev_sorted), "graph must be symmetric"
        # Rows sorted, no parallel edges.
        for v in range(min(n, 0)):  # pragma: no cover - spot check disabled
            nb = self.neighbors(v)
            assert np.all(np.diff(nb) > 0)

    # ------------------------------------------------------------------ #
    # Queries used by solvers / tests
    # ------------------------------------------------------------------ #
    def has_edge(self, u: int, v: int) -> bool:
        nb = self.neighbors(u)
        i = np.searchsorted(nb, v)
        return bool(i < nb.shape[0] and nb[i] == v)

    def is_independent_set(self, members: np.ndarray) -> bool:
        """members: [n] bool mask."""
        src = self.edge_sources()
        both = members[src] & members[self.indices]
        return not bool(np.any(both))

    def set_weight(self, members: np.ndarray) -> int:
        return int(self.weights[members].sum(dtype=np.int64))

    def induced_subgraph(self, keep: np.ndarray) -> Tuple["Graph", np.ndarray]:
        """Induced subgraph on `keep` (bool mask). Returns (graph, old_ids)."""
        old_ids = np.flatnonzero(keep)
        remap = -np.ones(self.n, dtype=np.int64)
        remap[old_ids] = np.arange(old_ids.shape[0])
        src = self.edge_sources()
        emask = keep[src] & keep[self.indices]
        new_src = remap[src[emask]]
        new_dst = remap[self.indices[emask]]
        return (
            from_directed_pairs(
                old_ids.shape[0],
                new_src.astype(np.int64),
                new_dst.astype(np.int64),
                self.weights[old_ids],
            ),
            old_ids,
        )


# ---------------------------------------------------------------------- #
# Constructors
# ---------------------------------------------------------------------- #
def from_edge_list(
    n: int,
    edges: Iterable[Tuple[int, int]],
    weights: np.ndarray,
) -> Graph:
    """Build from undirected edge list; dedups, drops self loops, symmetrizes."""
    e = np.asarray(list(edges), dtype=np.int64).reshape(-1, 2)
    if e.size:
        e = e[e[:, 0] != e[:, 1]]
        lo = np.minimum(e[:, 0], e[:, 1])
        hi = np.maximum(e[:, 0], e[:, 1])
        und = np.unique(np.stack([lo, hi], axis=1), axis=0)
        src = np.concatenate([und[:, 0], und[:, 1]])
        dst = np.concatenate([und[:, 1], und[:, 0]])
    else:
        src = np.zeros((0,), dtype=np.int64)
        dst = np.zeros((0,), dtype=np.int64)
    return from_directed_pairs(n, src, dst, weights)


def from_directed_pairs(
    n: int, src: np.ndarray, dst: np.ndarray, weights: np.ndarray
) -> Graph:
    """Build CSR from directed pairs (assumed already symmetric & loop-free)."""
    order = np.lexsort((dst, src))
    src = src[order]
    dst = dst[order]
    counts = np.bincount(src, minlength=n).astype(np.int64)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    g = Graph(
        indptr=indptr,
        indices=dst.astype(np.int32),
        weights=np.asarray(weights, dtype=np.int32),
    )
    return g


def relabel(g: Graph, perm: np.ndarray) -> Graph:
    """Relabel vertices: new id of old vertex v is perm[v]."""
    src = perm[g.edge_sources()]
    dst = perm[g.indices]
    w = np.empty_like(g.weights)
    w[perm] = g.weights
    return from_directed_pairs(g.n, src.astype(np.int64), dst.astype(np.int64), w)
