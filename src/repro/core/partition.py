"""1D vertex partition with ghost halos — the paper's machine model (§3).

Each PE i owns a contiguous block of vertices ``V_i`` (balanced by vertex
count or by edge count).  The local subgraph ``G_i`` contains:

  * all directed edges (u → v) with u ∈ V_i  (targets may be *ghosts*),
  * the reversed cut edges (ghost → local), i.e. the replicated local part
    ``N(g) ∩ V_i`` of every ghost's neighborhood — exactly what the paper
    replicates,
  * replicated ghost weights (upper bounds during reduction, Lemma 4.2).

SPMD/JAX adaptation: every per-PE array is padded to the maximum size over
PEs and stacked into a leading ``[p, ...]`` axis consumed by ``shard_map``.
A dedicated NIL vertex (local index ``L + G``) absorbs padding: weight 0,
status EXCLUDED, so masked segment ops ignore it without branches.

Halo routing is precomputed host-side:

  * board layout  — every PE publishes its interface vertices in a fixed
    order (`iface_slots`); ghosts address their owner's board via
    ``(ghost_owner_pe, ghost_owner_slot)``.  The baseline exchange is an
    ``all_gather`` of boards.
  * all_to_all routing — padded per-destination send lists
    (``send_slot``) and receive scatter lists (``recv_ghost``) for the
    bandwidth-optimal exchange (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.graph import Graph

# Status codes shared with the JAX rules.
UNDECIDED, INCLUDED, EXCLUDED, FOLDED = 0, 1, 2, 3


@dataclasses.dataclass
class PartitionedGraph:
    """Host-side partitioned graph; arrays stacked over the PE axis."""

    p: int
    n_global: int
    L: int  # padded local vertex count
    G: int  # padded ghost count
    E: int  # padded directed edge count (local rows + reversed cut edges)
    B: int  # padded interface-board size
    S: int  # padded per-destination send-list size (all_to_all exchange)
    D: int  # neighbor-window cap for capped rules

    starts: np.ndarray          # [p+1] block boundaries (global ids)
    row: np.ndarray             # [p, E] int32 local source index (pad = nil)
    col: np.ndarray             # [p, E] int32 local target index (pad = nil)
    w0: np.ndarray              # [p, V] int32 initial weights (V = L+G+1)
    gid: np.ndarray             # [p, V] int32 global id (pad/nil = -1)
    is_local: np.ndarray        # [p, V] bool
    is_ghost: np.ndarray        # [p, V] bool
    is_iface: np.ndarray        # [p, V] bool (local & has ghost neighbor)
    deg_local: np.ndarray       # [p, V] int32 (#edges with this row; exact
                                #  for locals, partial for ghosts)
    owner_pe: np.ndarray        # [p, V] int32 owning PE (self for locals)
    iface_slots: np.ndarray     # [p, B] int32 local idx of board slot (pad=nil)
    ghost_owner_slot: np.ndarray  # [p, G] int32 slot in owner board (pad=0)
    window: np.ndarray          # [p, V, D] int32 capped neighbor lists (pad=nil)
    win_complete: np.ndarray    # [p, V] bool (window holds the FULL PE-local
                                #  neighbor list)
    win_adj_bits: np.ndarray    # [p, V, D] int32 — bit j of [v, i] set iff
                                #  window[v, i] and window[v, j] are adjacent
                                #  (exact static adjacency; edges are never
                                #  inserted so this stays valid under masking)
    edge_common: np.ndarray     # [p, E, Dc] int32 — capped static common
                                #  neighborhood of each edge's endpoints
                                #  (lower-bound semantics for single-edge rules)
    Dc: int
    send_slot: np.ndarray       # [p, p, S] int32 board slots to send (pad=B)
    recv_ghost: np.ndarray      # [p, p, S] int32 ghost idx to scatter (pad=G)

    @property
    def V(self) -> int:
        return self.L + self.G + 1

    @property
    def nil(self) -> int:
        return self.L + self.G

    def local_of_global(self, pe: int, g: int) -> int:
        return int(g - self.starts[pe])

    def device_arrays(self) -> Dict[str, np.ndarray]:
        """The arrays the jitted reduction consumes (stacked over PEs)."""
        return dict(
            row=self.row, col=self.col, w0=self.w0, gid=self.gid,
            is_local=self.is_local, is_ghost=self.is_ghost,
            is_iface=self.is_iface, owner_pe=self.owner_pe,
            iface_slots=self.iface_slots,
            ghost_owner_slot=self.ghost_owner_slot,
            window=self.window, win_complete=self.win_complete,
            win_adj_bits=self.win_adj_bits, edge_common=self.edge_common,
            send_slot=self.send_slot, recv_ghost=self.recv_ghost,
        )


def _block_starts(g: Graph, p: int, edge_balanced: bool) -> np.ndarray:
    n = g.n
    if not edge_balanced:
        base = np.linspace(0, n, p + 1).astype(np.int64)
        return base
    # Edge-balanced contiguous split: cut points at equal shares of 2m.
    cum = g.indptr
    total = cum[-1]
    targets = np.linspace(0, total, p + 1)
    starts = np.searchsorted(cum, targets, side="left")
    starts[0], starts[-1] = 0, n
    starts = np.maximum.accumulate(starts)
    return starts.astype(np.int64)


def partition_graph(
    g: Graph,
    p: int,
    *,
    edge_balanced: bool = True,
    window_cap: int = 16,
    common_cap: int = 4,
    min_pad: int = 4,
    pad_to: Optional[Dict[str, int]] = None,
) -> PartitionedGraph:
    """`pad_to` (keys among L/G/E/B/S) forces minimum padded sizes so that
    different instances share one compiled program (shape bucketing)."""
    n = g.n
    starts = _block_starts(g, p, edge_balanced)
    src_all = g.edge_sources()

    per_pe = []
    for i in range(p):
        lo, hi = int(starts[i]), int(starts[i + 1])
        nloc = hi - lo
        e0, e1 = int(g.indptr[lo]), int(g.indptr[hi])
        esrc = src_all[e0:e1].astype(np.int64)
        edst = g.indices[e0:e1].astype(np.int64)
        remote = (edst < lo) | (edst >= hi)
        ghosts = np.unique(edst[remote])
        gmap = {int(gg): k for k, gg in enumerate(ghosts)}
        ngh = ghosts.shape[0]

        def loc(ids: np.ndarray) -> np.ndarray:
            out = np.empty(ids.shape[0], dtype=np.int64)
            inside = (ids >= lo) & (ids < hi)
            out[inside] = ids[inside] - lo
            out[~inside] = np.array(
                [nloc + gmap[int(x)] for x in ids[~inside]], dtype=np.int64
            ) if (~inside).any() else out[~inside]
            return out

        lsrc = esrc - lo
        ldst = loc(edst)
        # reversed cut edges: ghost -> local  (the replicated N(g) ∩ V_i)
        cut = ldst >= nloc
        rev_src = ldst[cut]
        rev_dst = lsrc[cut]
        rows = np.concatenate([lsrc, rev_src])
        cols = np.concatenate([ldst, rev_dst])
        order = np.lexsort((cols, rows))
        rows, cols = rows[order], cols[order]

        iface = np.zeros(nloc, dtype=bool)
        iface[lsrc[cut]] = True
        per_pe.append(
            dict(lo=lo, hi=hi, nloc=nloc, ghosts=ghosts, rows=rows,
                 cols=cols, iface=iface)
        )

    pad = pad_to or {}
    L = max(max((d["nloc"] for d in per_pe), default=1), 1, pad.get("L", 0))
    Gm = max(max((d["ghosts"].shape[0] for d in per_pe), default=0), min_pad,
             pad.get("G", 0))
    Em = max(max((d["rows"].shape[0] for d in per_pe), default=0), min_pad,
             pad.get("E", 0))
    Bm = max(max((int(d["iface"].sum()) for d in per_pe), default=0), min_pad,
             pad.get("B", 0))
    D = window_cap
    nil = L + Gm
    V = nil + 1

    row = np.full((p, Em), nil, dtype=np.int32)
    col = np.full((p, Em), nil, dtype=np.int32)
    w0 = np.zeros((p, V), dtype=np.int32)
    gid = np.full((p, V), -1, dtype=np.int32)
    is_local = np.zeros((p, V), dtype=bool)
    is_ghost = np.zeros((p, V), dtype=bool)
    is_iface = np.zeros((p, V), dtype=bool)
    deg_local = np.zeros((p, V), dtype=np.int32)
    owner_pe = np.full((p, V), -1, dtype=np.int32)
    iface_slots = np.full((p, Bm), nil, dtype=np.int32)
    ghost_owner_slot = np.zeros((p, Gm), dtype=np.int32)
    window = np.full((p, V, D), nil, dtype=np.int32)
    win_complete = np.zeros((p, V), dtype=bool)

    owner_of = np.searchsorted(starts, np.arange(n), side="right") - 1

    # First pass: fill per-PE vertex/edge arrays + boards.
    board_slot_of = []  # per PE: {global_id -> slot}
    for i, d in enumerate(per_pe):
        nloc, ghosts = d["nloc"], d["ghosts"]
        ne = d["rows"].shape[0]
        row[i, :ne] = d["rows"]
        col[i, :ne] = d["cols"]
        gids_local = np.arange(d["lo"], d["hi"], dtype=np.int32)
        gid[i, :nloc] = gids_local
        gid[i, L : L + ghosts.shape[0]] = ghosts.astype(np.int32)
        # remap ghost indices from nloc.. to L..
        shift = (d["rows"] >= nloc)
        row[i, :ne][shift] += L - nloc
        shift = (d["cols"] >= nloc)
        col[i, :ne][shift] += L - nloc
        w0[i, :nloc] = g.weights[d["lo"] : d["hi"]]
        w0[i, L : L + ghosts.shape[0]] = g.weights[ghosts]
        is_local[i, :nloc] = True
        is_ghost[i, L : L + ghosts.shape[0]] = True
        is_iface[i, :nloc] = d["iface"]
        owner_pe[i, :nloc] = i
        owner_pe[i, L : L + ghosts.shape[0]] = owner_of[ghosts]
        deg_local[i] = np.bincount(row[i, :ne], minlength=V).astype(np.int32)
        slots = np.flatnonzero(d["iface"])
        iface_slots[i, : slots.shape[0]] = slots
        board_slot_of.append(
            {int(gids_local[s]): k for k, s in enumerate(slots)}
        )
        # neighbor windows (first D neighbors in sorted col order per row)
        rr, cc = row[i, :ne], col[i, :ne]
        pos_in_row = np.zeros(ne, dtype=np.int64)
        if ne:
            newrow = np.ones(ne, dtype=bool)
            newrow[1:] = rr[1:] != rr[:-1]
            idx_start = np.zeros(V + 1, dtype=np.int64)
            uniq, cnt = np.unique(rr, return_counts=True)
            # position within row
            cs = np.cumsum(np.concatenate([[0], cnt]))
            starts_of_row = dict(zip(uniq.tolist(), cs[:-1].tolist()))
            pos_in_row = np.arange(ne) - np.array(
                [starts_of_row[int(x)] for x in rr]
            )
            small = pos_in_row < D
            window[i, rr[small], pos_in_row[small]] = cc[small]
        win_complete[i] = deg_local[i] <= D

    # Static window-pair adjacency bitmasks + capped per-edge common lists.
    Dc = common_cap
    win_adj_bits = np.zeros((p, V, D), dtype=np.int32)
    edge_common = np.full((p, Em, Dc), nil, dtype=np.int32)
    for i, d in enumerate(per_pe):
        ne = d["rows"].shape[0]
        rr, cc = row[i, :ne].astype(np.int64), col[i, :ne].astype(np.int64)
        keys = np.sort(rr * V + cc)

        def has_edge(a: np.ndarray, b: np.ndarray) -> np.ndarray:
            if keys.shape[0] == 0:
                return np.zeros(a.shape, dtype=bool)
            q = a * V + b
            pos = np.minimum(np.searchsorted(keys, q), keys.shape[0] - 1)
            return (keys[pos] == q) & (a != nil) & (b != nil)

        wnd = window[i].astype(np.int64)  # [V, D]
        for a_i in range(D):
            for b_i in range(D):
                if a_i == b_i:
                    continue
                adj = has_edge(wnd[:, a_i], wnd[:, b_i])
                win_adj_bits[i, :, a_i] |= adj.astype(np.int32) << b_i
        # Per-edge capped common neighborhood: window(u) ∩ window(v).
        if ne:
            wu = wnd[rr]          # [ne, D]
            wv = wnd[cc]          # [ne, D]
            # membership of wu entries in wv rows
            is_common = (wu[:, :, None] == wv[:, None, :]).any(-1)
            is_common &= wu != nil
            # take first Dc common entries
            rank = np.cumsum(is_common, axis=1) - 1
            sel = is_common & (rank < Dc)
            e_idx, k_idx = np.nonzero(sel)
            edge_common[i, e_idx, rank[sel]] = wu[sel].astype(np.int32)

    # Second pass: ghost -> owner board slots.
    for i, d in enumerate(per_pe):
        for k, gg in enumerate(d["ghosts"].tolist()):
            o = int(owner_of[gg])
            ghost_owner_slot[i, k] = board_slot_of[o][int(gg)]

    # all_to_all routing: PE i sends to PE j the boards entries of interface
    # vertices that are ghosts on j (sorted by gid for a canonical order).
    send_lists = [[[] for _ in range(p)] for _ in range(p)]
    recv_lists = [[[] for _ in range(p)] for _ in range(p)]
    for j, d in enumerate(per_pe):  # j = receiving PE (owns the ghosts)
        for k, gg in enumerate(d["ghosts"].tolist()):
            o = int(owner_of[gg])  # o = sending PE (owns vertex gg)
            send_lists[o][j].append(board_slot_of[o][int(gg)])
            recv_lists[j][o].append(k)
    Sm = max(
        max((len(send_lists[i][j]) for i in range(p) for j in range(p)),
            default=0),
        1,
        pad.get("S", 0),
    )
    send_slot = np.full((p, p, Sm), Bm, dtype=np.int32)
    recv_ghost = np.full((p, p, Sm), Gm, dtype=np.int32)
    for i in range(p):
        for j in range(p):
            s = send_lists[i][j]
            send_slot[i, j, : len(s)] = s
            r = recv_lists[i][j]
            recv_ghost[i, j, : len(r)] = r

    return PartitionedGraph(
        p=p, n_global=n, L=L, G=Gm, E=Em, B=Bm, S=Sm, D=D,
        starts=starts, row=row, col=col, w0=w0, gid=gid,
        is_local=is_local, is_ghost=is_ghost, is_iface=is_iface,
        deg_local=deg_local, owner_pe=owner_pe, iface_slots=iface_slots,
        ghost_owner_slot=ghost_owner_slot, window=window,
        win_complete=win_complete, win_adj_bits=win_adj_bits,
        edge_common=edge_common, Dc=Dc,
        send_slot=send_slot, recv_ghost=recv_ghost,
    )


def compact_partition(
    pg: PartitionedGraph,
    status: np.ndarray,
    w: np.ndarray,
    *,
    pad_to: Optional[Dict[str, int]] = None,
    min_pad: int = 4,
) -> PartitionedGraph:
    """Exact shape-descent compaction: the *restriction* of ``pg`` to its
    alive (UNDECIDED) kernel, with the current folded weights as ``w0``.

    This is deliberately NOT a fresh :func:`partition_graph` of the
    residual.  The staged solver's bit-identity guarantee rests on the
    restricted instance making every rule test, greedy beat test, peel
    argmax and exchange reconciliation compute exactly the values the
    full-shape run would compute on its alive slots:

      * per-PE ownership is preserved — every alive local/ghost stays on
        its PE, so per-PE peel argmax sets and board routing are unchanged;
      * slot maps are monotone (alive locals keep order, alive ghosts keep
        order, locals stay below ghosts), so the lexsorted edge order — the
        sorted-segment invariant of the aggregate engine — survives verbatim;
      * windows keep their *positions*: dead entries become nil (inactive,
        like any decided vertex) instead of being recomputed, so
        ``win_adj_bits`` copies bit-for-bit and capped-rule activation
        masks match the full-shape run; ``win_complete``/``is_iface`` are
        copied, never recomputed (a fresh partition would fire MORE rules
        than the full-shape run and break parity);
      * global ids are copied (NOT relabelled): rules only compare gids and
        test ``gid >= 0``, so non-contiguous gids are fine — and stitching
        stays a direct lookup in the original id space.

    ``status``/``w`` are the union-layout [p*V] (or [p, V]) arrays of the
    current :class:`repro.core.rules.RedState`.  Requires an
    exchange-consistent state (ghost slot alive iff its owner's copy is
    alive) — true at every post-exchange round boundary; raises
    ``ValueError`` otherwise.  Weights go through
    :func:`repro.core.validate.residual_weights` (the ``bad_weight`` gate
    for folded-weight overflow).  ``pad_to`` keys L/G/E/B/S floor the
    padded sizes (ladder-cell bucketing); actual per-PE maxima win when
    they exceed the floor.
    """
    from repro.core import validate as VAL

    p, V, L, G = pg.p, pg.V, pg.L, pg.G
    status = np.asarray(status).reshape(p, V)
    w = np.asarray(w).reshape(p, V)
    alive = status == UNDECIDED
    keep_l = pg.is_local & alive
    keep_g = pg.is_ghost & alive
    keep = keep_l | keep_g

    per = []
    for i in range(p):
        kl = np.flatnonzero(keep_l[i])
        kg = np.flatnonzero(keep_g[i])
        ke = np.flatnonzero(keep[i][pg.row[i]] & keep[i][pg.col[i]])
        per.append((kl, kg, ke))

    pad = pad_to or {}
    L2 = max(max(kl.size for kl, _, _ in per), 1, pad.get("L", 0))
    G2 = max(max(kg.size for _, kg, _ in per), min_pad, pad.get("G", 0))
    E2 = max(max(ke.size for _, _, ke in per), min_pad, pad.get("E", 0))
    B2 = max(max(int((keep_l[i] & pg.is_iface[i]).sum()) for i in range(p)),
             min_pad, pad.get("B", 0))
    nil2 = L2 + G2
    V2 = nil2 + 1
    D, Dc = pg.D, pg.Dc

    row = np.full((p, E2), nil2, dtype=np.int32)
    col = np.full((p, E2), nil2, dtype=np.int32)
    w0 = np.zeros((p, V2), dtype=np.int32)
    gid = np.full((p, V2), -1, dtype=np.int32)
    is_local = np.zeros((p, V2), dtype=bool)
    is_ghost = np.zeros((p, V2), dtype=bool)
    is_iface = np.zeros((p, V2), dtype=bool)
    deg_local = np.zeros((p, V2), dtype=np.int32)
    owner_pe = np.full((p, V2), -1, dtype=np.int32)
    iface_slots = np.full((p, B2), nil2, dtype=np.int32)
    window = np.full((p, V2, D), nil2, dtype=np.int32)
    win_complete = np.zeros((p, V2), dtype=bool)
    win_adj_bits = np.zeros((p, V2, D), dtype=np.int32)
    edge_common = np.full((p, E2, Dc), nil2, dtype=np.int32)

    board_slot_of = []  # per PE: {global_id -> new board slot}
    slot_maps = []
    for i, (kl, kg, ke) in enumerate(per):
        smap = np.full(V, nil2, dtype=np.int32)
        smap[kl] = np.arange(kl.size, dtype=np.int32)
        smap[kg] = L2 + np.arange(kg.size, dtype=np.int32)
        slot_maps.append(smap)
        old = np.concatenate([kl, kg])
        new = smap[old]
        # monotone map ⇒ the kept subsequence of the lexsorted edge list
        # stays lexsorted after remapping
        ne = ke.size
        row[i, :ne] = smap[pg.row[i, ke]]
        col[i, :ne] = smap[pg.col[i, ke]]
        w0[i, new] = VAL.residual_weights(
            w[i, old], where=f"compact pe{i}")
        gid[i, new] = pg.gid[i, old]
        is_local[i, smap[kl]] = True
        is_ghost[i, smap[kg]] = True
        is_iface[i, new] = pg.is_iface[i, old]
        owner_pe[i, new] = pg.owner_pe[i, old]
        deg_local[i] = np.bincount(
            row[i, :ne], minlength=V2).astype(np.int32)
        window[i, new] = smap[pg.window[i, old]]
        win_complete[i, new] = pg.win_complete[i, old]
        win_adj_bits[i, new] = pg.win_adj_bits[i, old]
        if ne:
            edge_common[i, :ne] = smap[pg.edge_common[i, ke]]
        slots = smap[np.flatnonzero(keep_l[i] & pg.is_iface[i])]
        iface_slots[i, : slots.size] = slots
        board_slot_of.append(
            {int(gid[i, s]): k for k, s in enumerate(slots)}
        )

    # ghost -> owner board routing (old ghost order = sorted by gid).
    ghost_owner_slot = np.zeros((p, G2), dtype=np.int32)
    send_lists = [[[] for _ in range(p)] for _ in range(p)]
    recv_lists = [[[] for _ in range(p)] for _ in range(p)]
    for j, (_, kg, _) in enumerate(per):
        for k2, s in enumerate(kg.tolist()):
            gg = int(pg.gid[j, s])
            o = int(pg.owner_pe[j, s])
            slot = board_slot_of[o].get(gg)
            if slot is None:
                raise ValueError(
                    "compact_partition needs an exchange-consistent state: "
                    f"ghost gid {gg} is alive on pe{j} but its owner copy "
                    f"on pe{o} is not (descend only at post-exchange round "
                    "boundaries)")
            ghost_owner_slot[j, k2] = slot
            send_lists[o][j].append(slot)
            recv_lists[j][o].append(k2)
    S2 = max(
        max((len(send_lists[i][j]) for i in range(p) for j in range(p)),
            default=0),
        1, pad.get("S", 0),
    )
    send_slot = np.full((p, p, S2), B2, dtype=np.int32)
    recv_ghost = np.full((p, p, S2), G2, dtype=np.int32)
    for i in range(p):
        for j in range(p):
            s = send_lists[i][j]
            send_slot[i, j, : len(s)] = s
            r = recv_lists[i][j]
            recv_ghost[i, j, : len(r)] = r

    return PartitionedGraph(
        p=p, n_global=pg.n_global, L=L2, G=G2, E=E2, B=B2, S=S2, D=D,
        starts=pg.starts, row=row, col=col, w0=w0, gid=gid,
        is_local=is_local, is_ghost=is_ghost, is_iface=is_iface,
        deg_local=deg_local, owner_pe=owner_pe, iface_slots=iface_slots,
        ghost_owner_slot=ghost_owner_slot, window=window,
        win_complete=win_complete, win_adj_bits=win_adj_bits,
        edge_common=edge_common, Dc=Dc,
        send_slot=send_slot, recv_ghost=recv_ghost,
    )


def gather_global_members(
    pg: PartitionedGraph, status: np.ndarray
) -> np.ndarray:
    """Assemble the global member mask from per-PE INCLUDED statuses."""
    members = np.zeros(pg.n_global, dtype=bool)
    for i in range(pg.p):
        loc = pg.is_local[i]
        inc = loc & (status[i] == INCLUDED)
        members[pg.gid[i][inc]] = True
    return members
