"""Vectorized masked reduction rules — the paper's §4.3 in JAX array form.

Every rule is evaluated for *all* vertices of a PE's local subgraph at once
(segment reductions over the edge list + static capped neighbor windows),
instead of the per-vertex worklist of a sequential CPU reducer.  This is the
TPU-native re-expression of the paper's observation that the rules "act very
locally": locality means each test is a bounded neighborhood aggregate, i.e.
exactly a masked segment op.

Batching soundness.  A sequential reducer applies one rule at a time; a
vectorized sweep fires many applications simultaneously, which is unsound
without care (two adjacent vertices both passing an include test must not
both be included; two vertices excluding each other via symmetric
single-edge certificates would lose the optimum).  We restore soundness
with deterministic priority filters (global vertex id = the paper's
PE-rank/ID tie-breaking generalised to every rule):

  * include rules   — candidates are accepted only if they beat every
    candidate neighbor (accepted set is independent; include rules are
    monotone under deletion of other accepted vertices, so a batch equals
    some sequential order).
  * exclude rules   — a vertex is excluded only if its certificate vertex
    has *higher* priority; certificate chains therefore strictly ascend and
    the standard rerouting argument (any solution using an excluded vertex
    can be rerouted toward higher-priority certificates) terminates.
  * weight transfer — accepted folds must be the unique candidate within
    two hops, so their closed neighborhoods are disjoint and the batched
    weight decrements cannot race.

Ghost semantics follow the distributed reduction model (Def. 4.1):
ghost weights are upper bounds (Lemma 4.2), neighborhoods are supersets
(Lemma 4.3); every test below is monotone in the right direction so stale
border data only ever makes a rule *more conservative*, never unsound.
Interface-vertex includes are proposals (Remark 4.6); conflict resolution
happens in the exchange step (:mod:`repro.core.distributed`).

Aggregate-declaration contract (see ARCHITECTURE.md): every rule *declares*
the neighborhood aggregates its TEST needs via ``@_requires(...)`` and
receives them in a :class:`SweepCtx` — rules never issue their own segment
reductions for tests.  The aggregate engine (:mod:`repro.core.engine`)
computes the union of the scheduled rules' requirements and dispatches the
segment reductions through a pluggable backend (jnp or the Pallas
blocked-ELL kernels).  Rule *applications* (scatters, certificate activity)
always read fresh status — those stay inline here.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.ops import segment_max, segment_sum

from repro.kernels.wedge_intersect.ops import window_active_bits


def _requires(*aggs: str):
    """Declare which SweepCtx aggregates a rule's test consumes."""
    unknown = set(aggs) - set(SweepCtx._fields)
    if unknown:
        raise ValueError(
            f"unknown aggregate(s) {sorted(unknown)}; "
            f"SweepCtx fields are {SweepCtx._fields}"
        )

    def deco(fn):
        fn.requires = frozenset(aggs)
        return fn

    return deco

UNDECIDED, INCLUDED, EXCLUDED, FOLDED = 0, 1, 2, 3
LOG_FOLD1, LOG_WT = 1, 2

I32_MIN = jnp.iinfo(jnp.int32).min


class Aux(NamedTuple):
    """Static (per-PE) graph structure; never modified by reductions."""

    row: jax.Array            # [E] i32 source local idx (pad = nil)
    col: jax.Array            # [E] i32 target local idx (pad = nil)
    gid: jax.Array            # [V] i32 global id (nil/pad = -1)
    is_local: jax.Array       # [V] bool
    is_iface: jax.Array       # [V] bool
    owner_rank: jax.Array     # [V] i32 owning PE (tie-breaking, Lemma 4.5)
    window: jax.Array         # [V, D] i32 capped neighbor lists (pad = nil)
    win_complete: jax.Array   # [V] bool
    win_adj_bits: jax.Array   # [V, D] i32 static pairwise adjacency bits
    edge_common: jax.Array    # [E, Dc] i32 capped common neighborhoods


class RedState(NamedTuple):
    """Mutable reduction state (one PE)."""

    w: jax.Array        # [V] i32 current weights
    status: jax.Array   # [V] i8
    log_kind: jax.Array  # [LOG] i8   (fold log for reconstruction)
    log_v: jax.Array    # [LOG] i32
    log_u: jax.Array    # [LOG] i32
    log_n: jax.Array    # [] i32
    offset: jax.Array   # [] i32  (weight reclaimed by folds; reporting)
    changed: jax.Array  # [] bool (any rule fired in the current sweep)


def init_state(w0: jax.Array, is_local: jax.Array, is_ghost: jax.Array) -> RedState:
    V = w0.shape[0]
    L = int(is_local.shape[0])
    status = jnp.where(is_local | is_ghost, UNDECIDED, EXCLUDED).astype(jnp.int8)
    log_cap = V + 1  # each fold retires one vertex forever => never overflows
    return RedState(
        w=w0.astype(jnp.int32),
        status=status,
        log_kind=jnp.zeros(log_cap, jnp.int8),
        log_v=jnp.zeros(log_cap, jnp.int32),
        log_u=jnp.zeros(log_cap, jnp.int32),
        log_n=jnp.zeros((), jnp.int32),
        offset=jnp.zeros((), jnp.int32),
        changed=jnp.zeros((), bool),
    )


# --------------------------------------------------------------------- #
# shared masked aggregates
# --------------------------------------------------------------------- #
def _active(state: RedState) -> jax.Array:
    return state.status == UNDECIDED


def _edge_active(aux: Aux, active: jax.Array) -> jax.Array:
    return active[aux.row] & active[aux.col]


def _aw(state: RedState, active: jax.Array) -> jax.Array:
    return jnp.where(active, state.w, 0)


def _act_deg(aux: Aux, eact: jax.Array, V: int) -> jax.Array:
    return segment_sum(eact.astype(jnp.int32), aux.row, num_segments=V)


def _accept_independent(
    aux: Aux, eact: jax.Array, cand: jax.Array, V: int
) -> jax.Array:
    """Filter include candidates to an independent set (gid priority)."""
    nbr_cand_gid = jnp.where(eact & cand[aux.col], aux.gid[aux.col], -1)
    m = segment_max(nbr_cand_gid, aux.row, num_segments=V)
    m = jnp.maximum(m, -1)
    return cand & (aux.gid > m)


def _apply_include(
    state: RedState, aux: Aux, eact: jax.Array, accept: jax.Array
) -> RedState:
    status = jnp.where(accept, jnp.int8(INCLUDED), state.status)
    hit = segment_max(
        (accept[aux.row] & eact).astype(jnp.int32), aux.col,
        num_segments=state.w.shape[0],
    ) > 0
    status = jnp.where(hit & (status == UNDECIDED), jnp.int8(EXCLUDED), status)
    return state._replace(status=status, changed=state.changed | accept.any())


def _log_append(
    state: RedState, mask: jax.Array, kind: int, v_idx: jax.Array,
    u_idx: jax.Array
) -> RedState:
    cap = state.log_kind.shape[0]
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    pos = jnp.where(mask, state.log_n + rank, cap - 1)
    # cap-1 slot is a scratch sentinel; log_n never reaches it (see init_state)
    log_kind = state.log_kind.at[pos].set(jnp.where(mask, jnp.int8(kind), 0))
    log_v = state.log_v.at[pos].set(jnp.where(mask, v_idx, 0))
    log_u = state.log_u.at[pos].set(jnp.where(mask, u_idx, 0))
    n = state.log_n + mask.sum(dtype=jnp.int32)
    return state._replace(log_kind=log_kind, log_v=log_v, log_u=log_u, log_n=n)


class SweepCtx(NamedTuple):
    """Rule-test aggregates, produced by the engine's pluggable backend.

    The engine (:mod:`repro.core.engine`) fills exactly the fields the
    scheduled rules declared via ``@_requires`` — undeclared fields are
    ``None``, so a rule reading past its declaration fails loudly.

    Staleness soundness (EXPERIMENTS.md §Perf H3): when the schedule
    snapshots aggregates once per sweep, adjacency is static and
    weights/activity only decrease, so snapshot aggregates are upper bounds
    of their fresh values — every rule test is monotone in the safe
    direction.  Rule *applications* and certificate activity always use
    fresh status (recomputed eact), so cross-family conflicts inside one
    sweep cannot arise."""

    S: Optional[jax.Array]         # [V] neighborhood weight sums
    deg: Optional[jax.Array]       # [V] active degrees
    M: Optional[jax.Array]         # [V] max neighbor weight
    only: Optional[jax.Array]      # [V] the unique active neighbor (deg-1)
    act_bits: Optional[jax.Array]  # [V] window active bits
    clique: Optional[jax.Array]    # [V] active window forms a clique


# --------------------------------------------------------------------- #
# rule: degree zero / one  (Meta rule + Remark 4.8, fold form of Gu et al.)
# --------------------------------------------------------------------- #
@_requires("deg", "only")
def rule_degree_one(state: RedState, aux: Aux, ctx: SweepCtx) -> RedState:
    V = state.w.shape[0]
    active = _active(state)
    eact = _edge_active(aux, active)
    deg, only = ctx.deg, ctx.only
    w_u = state.w[only]

    # (a) isolated vertices
    acc0 = aux.is_local & active & (deg == 0)
    state = _apply_include(state, aux, eact, acc0)

    # (b) degree-one include: w(v) >= w_i(u)  — upper bound is enough
    #     (ghost case: propose per Remark 4.6)
    active = _active(state)
    eact = _edge_active(aux, active)
    cand = aux.is_local & active & (deg == 1) & (state.w >= w_u)
    acc1 = _accept_independent(aux, eact, cand, V)
    state = _apply_include(state, aux, eact, acc1)

    # (c) degree-one fold: w(v) < w(u), u local:
    #       w(u) -= w(v);  v FOLDED;  v ∈ I  iff  u ∉ I.
    active = _active(state)
    cand = aux.is_local & active & (deg == 1) & (state.w < w_u)
    cand &= aux.is_local[only] & active[only]
    # one fold per target u per sweep: keep the max-gid candidate
    tgt = jnp.where(cand, only, V - 1)
    best = jnp.full(V, -1, jnp.int32).at[tgt].max(jnp.where(cand, aux.gid, -1))
    acc = cand & (aux.gid == best[only])
    w = state.w.at[jnp.where(acc, only, V - 1)].add(
        jnp.where(acc, -state.w, 0)
    )
    w = w.at[V - 1].set(0)
    status = jnp.where(acc, jnp.int8(FOLDED), state.status)
    offset = state.offset + jnp.where(acc, state.w, 0).sum(dtype=jnp.int32)
    state = state._replace(
        w=w, status=status, offset=offset, changed=state.changed | acc.any()
    )
    idx = jnp.arange(V, dtype=jnp.int32)
    return _log_append(state, acc, LOG_FOLD1, idx, only.astype(jnp.int32))


# --------------------------------------------------------------------- #
# rule: Dist. Neighborhood Removal (Reduction 4.3)
# --------------------------------------------------------------------- #
@_requires("S")
def rule_neighborhood_removal(state: RedState, aux: Aux,
                              ctx: SweepCtx) -> RedState:
    V = state.w.shape[0]
    active = _active(state)
    eact = _edge_active(aux, active)
    s = ctx.S
    cand = aux.is_local & active & (state.w >= s)
    acc = _accept_independent(aux, eact, cand, V)
    return _apply_include(state, aux, eact, acc)


# --------------------------------------------------------------------- #
# rule: Distributed Simplicial Vertex (Reduction 4.4)
# --------------------------------------------------------------------- #
@_requires("clique", "M")
def rule_simplicial(state: RedState, aux: Aux, ctx: SweepCtx) -> RedState:
    V = state.w.shape[0]
    active = _active(state)
    eact = _edge_active(aux, active)
    clique, m = ctx.clique, ctx.M
    cand = (
        aux.is_local & active & aux.win_complete & clique & (state.w >= m)
    )
    acc = _accept_independent(aux, eact, cand, V)
    return _apply_include(state, aux, eact, acc)


# --------------------------------------------------------------------- #
# rule: Dist. Simplicial Weight Transfer (Reduction 4.5)
# --------------------------------------------------------------------- #
@_requires("clique", "M", "deg")
def rule_weight_transfer(state: RedState, aux: Aux,
                         ctx: SweepCtx) -> RedState:
    V = state.w.shape[0]
    D = aux.window.shape[1]
    active = _active(state)
    eact = _edge_active(aux, active)
    clique, m, deg = ctx.clique, ctx.M, ctx.deg

    # v must be max-weight among the simplicial vertices of N(v).  A neighbor
    # whose simpliciality we cannot decide (incomplete window) blocks v.
    simpl_known = aux.win_complete & clique
    nbr_blocks = eact & (state.w[aux.col] > state.w[aux.row]) & (
        simpl_known[aux.col] | ~aux.win_complete[aux.col]
    )
    blocked = segment_max(
        nbr_blocks.astype(jnp.int32), aux.row, num_segments=V
    ) > 0

    cand = (
        aux.is_local & active & ~aux.is_iface & simpl_known
        & (state.w < m) & ~blocked & (deg >= 1)
    )
    # unique within two hops (gid priority) => disjoint closed neighborhoods
    m1 = segment_max(
        jnp.where(eact & cand[aux.col], aux.gid[aux.col], -1), aux.row,
        num_segments=V,
    )
    m1 = jnp.maximum(m1, -1)
    m2 = segment_max(jnp.where(eact, m1[aux.col], -1), aux.row, num_segments=V)
    m2 = jnp.maximum(m2, -1)
    acc = cand & (aux.gid > m1) & (aux.gid >= m2)

    # apply the fold: remove X = {u in N[v]: w(u) <= w(v)}, transfer weight.
    # entry activity here must be FRESH (application, not test): recompute
    # from current status via the vectorized window helper, not from ctx
    fresh_bits = window_active_bits(_active(state), aux.gid, aux.window)
    wv = state.w
    tgt = aux.window  # [V, D]
    ent_active = ((fresh_bits[:, None] >> jnp.arange(D)[None, :]) & 1) == 1
    accb = acc[:, None]
    excl_upd = accb & ent_active & (state.w[tgt] <= wv[:, None])
    dec_upd = accb & ent_active & (state.w[tgt] > wv[:, None])
    nil_slot = V - 1
    # plain EXCLUDED fill: non-accepted slots scatter onto the nil slot,
    # which is EXCLUDED by invariant, so the unconditional value is safe
    status = state.status.at[jnp.where(excl_upd, tgt, nil_slot)].set(
        jnp.int8(EXCLUDED)
    )
    status = jnp.where(acc, jnp.int8(FOLDED), status)
    w = state.w.at[jnp.where(dec_upd, tgt, nil_slot)].add(
        jnp.where(dec_upd, -wv[:, None], 0)
    )
    w = w.at[nil_slot].set(0)
    offset = state.offset + jnp.where(acc, wv, 0).sum(dtype=jnp.int32)
    state = state._replace(
        w=w, status=status, offset=offset, changed=state.changed | acc.any()
    )
    idx = jnp.arange(V, dtype=jnp.int32)
    return _log_append(state, acc, LOG_WT, idx, idx)


# --------------------------------------------------------------------- #
# rule: Distributed Basic Single-Edge (Reduction 4.6)
# --------------------------------------------------------------------- #
@_requires("S")
def rule_basic_single_edge(state: RedState, aux: Aux,
                           ctx: SweepCtx) -> RedState:
    V = state.w.shape[0]
    active = _active(state)
    eact = _edge_active(aux, active)
    aw = _aw(state, active)
    s = ctx.S
    # capped common-neighborhood weight (lower bound => conservative)
    c = jnp.where(
        active[aux.edge_common], aw[aux.edge_common], 0
    ).sum(axis=1)
    val = s[aux.row] - c  # >= true ω(N(u) \ N(v)) which contains v itself
    test = (
        eact
        & aux.is_local[aux.row] & aux.is_local[aux.col]
        & (val <= state.w[aux.row])
        & (aux.gid[aux.row] > aux.gid[aux.col])  # ascending certificate chain
    )
    excl = segment_max(test.astype(jnp.int32), aux.col, num_segments=V) > 0
    status = jnp.where(
        excl & active & aux.is_local, jnp.int8(EXCLUDED), state.status
    )
    fired = (excl & active & aux.is_local).any()
    return state._replace(status=status, changed=state.changed | fired)


# --------------------------------------------------------------------- #
# rule: Dist. Extended Single-Edge (Reduction 4.7)
# --------------------------------------------------------------------- #
@_requires("S")
def rule_extended_single_edge(state: RedState, aux: Aux,
                              ctx: SweepCtx) -> RedState:
    V = state.w.shape[0]
    active = _active(state)
    eact = _edge_active(aux, active)
    aw = _aw(state, active)
    s = ctx.S
    # edge e = (v=row, u=col):  w(v) >= S(v) - aw(u)  => exclude common nbrs
    test = (
        eact
        & aux.is_local[aux.row] & aux.is_local[aux.col]
        & (s[aux.row] - aw[aux.col] <= state.w[aux.row])
    )
    min_gid = jnp.minimum(aux.gid[aux.row], aux.gid[aux.col])
    tgt = aux.edge_common  # [E, Dc]
    upd = (
        test[:, None]
        & active[tgt] & aux.is_local[tgt]
        & (aux.gid[tgt] < min_gid[:, None])
        & (aux.gid[tgt] >= 0)
    )
    nil_slot = V - 1
    status = state.status.at[jnp.where(upd, tgt, nil_slot)].set(jnp.int8(EXCLUDED))
    fired = upd.any()
    return state._replace(status=status, changed=state.changed | fired)


# --------------------------------------------------------------------- #
# rule: Distributed Heavy Vertex (Reduction 4.2) — exact sub-MWIS
# --------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("heavy_k",))
def _alpha_neighborhood(
    w: jax.Array, status: jax.Array, aux: Aux, heavy_k: int
) -> jax.Array:
    """[V] i32 — exact α(G_i[N_i(v)]) for active windows with ≤K active
    entries; 2^K subset enumeration against static adjacency bitmasks (the
    KaMIS-wB&R stand-in, vectorised for the VPU/MXU)."""
    V, D = aux.window.shape
    K = heavy_k
    active = status == UNDECIDED
    ent_ok = active[aux.window] & (aux.gid[aux.window] >= 0)  # [V, D]
    # stable-sort entries: active first, keep the first K
    order = jnp.argsort(~ent_ok, axis=1, stable=True)[:, :K]  # [V, K]
    ent = jnp.take_along_axis(aux.window, order, axis=1)      # [V, K]
    ent_act = jnp.take_along_axis(ent_ok, order, axis=1)      # [V, K]
    wk = jnp.where(ent_act, w[ent], 0).astype(jnp.int32)      # [V, K]
    # permuted adjacency bits: bit j of row i = adjacency(order_i, order_j)
    bits_full = jnp.take_along_axis(aux.win_adj_bits, order, axis=1)  # [V, K]
    adj = jnp.zeros((V, K), jnp.int32)
    for j in range(K):
        oj = order[:, j]
        bit_j = (bits_full >> oj[:, None]) & 1  # [V, K] adjacency to entry j
        adj |= bit_j << j
    subsets = jnp.arange(1 << K, dtype=jnp.int32)               # [T]
    sel = ((subsets[:, None] >> jnp.arange(K)[None, :]) & 1)     # [T, K]
    totals = wk @ sel.T.astype(jnp.int32)                        # [V, T]
    conflict = jnp.zeros(totals.shape, bool)
    for i in range(K):
        in_sub = sel[:, i] == 1                                  # [T]
        hits = (subsets[None, :] & adj[:, i : i + 1]) != 0       # [V, T]
        conflict |= in_sub[None, :] & hits
    alpha = jnp.where(conflict, -1, totals).max(axis=1)
    return jnp.maximum(alpha, 0)


def rule_heavy_vertex(state: RedState, aux: Aux, heavy_k: int = 8) -> RedState:
    V = state.w.shape[0]
    active = _active(state)
    eact = _edge_active(aux, active)
    deg = _act_deg(aux, eact, V)
    alpha = _alpha_neighborhood(state.w, state.status, aux, heavy_k)
    cand = (
        aux.is_local & active & aux.win_complete
        & (deg <= heavy_k) & (state.w >= alpha)
    )
    acc = _accept_independent(aux, eact, cand, V)
    return _apply_include(state, aux, eact, acc)


def reconstruct_members(state: RedState, aux: Aux) -> jax.Array:
    """Replay the fold log in reverse; returns [V] bool membership.

    INCLUDED statuses seed the set; FOLD1 (v ∈ I ⟺ u ∉ I) and WT
    (v ∈ I ⟺ I ∩ N(v) = ∅, window-complete by rule gating) records replay
    newest-first.  All record targets are local by rule construction.

    The body masks iterations ≥ log_n onto the nil slot: under vmap (the
    batched serving path) the lowered while_loop runs every batch element
    for the max trip count, and an unguarded body would re-apply a clamped
    log record to a real vertex.  Writing False to the nil slot is inert —
    nil is never local, so it is never reported as a member.
    """
    in_set = state.status == INCLUDED
    nil = state.status.shape[0] - 1

    def body(i, in_set):
        live = i < state.log_n
        k = jnp.maximum(state.log_n - 1 - i, 0)
        kind = state.log_kind[k]
        v = state.log_v[k]
        u = state.log_u[k]
        fold1_val = ~in_set[u]
        wt_entries = aux.window[v]
        wt_val = ~(in_set[wt_entries] & (aux.gid[wt_entries] >= 0)).any()
        val = jnp.where(kind == LOG_FOLD1, fold1_val, wt_val)
        return in_set.at[jnp.where(live, v, nil)].set(live & val)

    return jax.lax.fori_loop(0, state.log_n, body, in_set)
