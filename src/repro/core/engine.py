"""Aggregate engine — one pluggable backend for every segment reduction.

The paper's reduction rules "act very locally": every rule *test* is a
bounded neighborhood aggregate (sum / max over the masked edge list, plus
capped-window clique bits).  Rule portfolios keep growing (Großmann et al.'s
rule survey, the KaMIS reduce-and-peel line), which is only sustainable if
rules *declare* the aggregates they need and a single engine computes them —
once per sweep, on the fastest available backend — instead of every rule
family issuing its own ad-hoc segment reductions.

Three pieces:

  * **declarations** — each rule in :mod:`repro.core.rules` carries a
    ``requires`` frozenset (``@_requires``) naming the :class:`SweepCtx`
    fields its test reads.  The engine computes exactly the union of the
    scheduled rules' requirements; undeclared fields stay ``None``.
  * **schedules** — the rule order is data, not code: a named
    :class:`Schedule` lists the rule families to run and the aggregate
    *refresh* granularity:

      - ``refresh="rule"``  — aggregates recomputed before every rule
        (the seed PR's exact per-rule semantics; parity oracle in
        ``tests/seed_oracle.py``),
      - ``refresh="sweep"`` — aggregates snapshotted ONCE per sweep and
        shared by all families (the fused hot path; tests go conservatively
        stale, applications stay fresh — see the SweepCtx docstring and
        ARCHITECTURE.md for the soundness argument).

  * **backends** — :func:`aggregate` is the single entry point for segment
    reductions over the static edge list.  The rule sweep, the greedy /
    reduce-and-peel solvers and the halo-exchange conflict resolution all
    route through it:

      - ``"jnp"``     — ``jax.ops.segment_*`` (portable; XLA sort-based;
        the row array is sorted by partition construction, so the engine
        passes ``indices_are_sorted``),
      - ``"blocked"`` — blocked-ELL layout via the precomputed
        :class:`SegPlan` packing, jnp per-block reference kernels,
      - ``"pallas"``  — the same blocked-ELL layout through the fused
        multi-payload Pallas kernel (`kernels/segment_coo`), one pass over
        the packed edge blocks for all sum+max+min+bitwise-OR payloads
        (interpret mode off TPU).

    All payloads are int32, and integer addition is associative, so all
    three backends are **bit-identical** — backend choice is purely a
    performance decision.

Window bits through the edge pass.  The capped-window activity bits and the
clique test are *also* edge-local: every window entry ``window[v, i]`` is by
construction one of v's edges, so the static plan carries, per edge
``(v, u)``, the window-position bit ``wbits = Σ_i [window[v,i]=u] << i`` and
the clique-violation mask ``wnh = OR_i [window[v,i]=u] ~(adj_bits[v,i] |
1<<i)``.  One bitwise-OR column pair in the fused pass then yields

    act_bits(v) = OR_{u ∈ N(v) active} wbits(v,u)
    clique(v)   = (act_bits(v) & OR_{u active} wnh(v,u)) == 0

bit-identical to the seed's D-unrolled window gather loop (the ``need &
~have`` test distributes over the OR), with zero extra traversals.  The jnp
backend computes the same bits from the [V, D] window layout
(:func:`repro.kernels.wedge_intersect.ops.window_active_bits`) — cheaper
there than a sort-based segment pass.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.ops import segment_max, segment_min, segment_sum

from repro.core import rules as R
from repro.kernels.segment_coo.ops import (
    pack_blocks, pack_blocks_stacked, segment_fused_coo,
)
from repro.kernels.segment_coo.ref import segment_or_ref
from repro.kernels.wedge_intersect import ops as W

I32_MIN = jnp.iinfo(jnp.int32).min

#: SweepCtx fields a rule may declare via @_requires (validated there).
AGGREGATES = R.SweepCtx._fields

#: Aggregate backends (see module docstring).
BACKENDS = ("jnp", "blocked", "pallas")

#: Default row-block height of the blocked-ELL packing (sublane-aligned).
R_BLK = 8

#: Candidate row-block heights for plan-build-time autotuning.
R_BLK_CANDIDATES = (8, 16, 32, 64)

#: Edge-budget alignment of the packing (int32 sublane multiple).
E_BLK_MULTIPLE = 8

#: Rule registry: schedule entries name rules; order comes from Schedule.
RULES = {
    "degree_one": R.rule_degree_one,
    "neighborhood_removal": R.rule_neighborhood_removal,
    "weight_transfer": R.rule_weight_transfer,
    "simplicial": R.rule_simplicial,
    "basic_single_edge": R.rule_basic_single_edge,
    "extended_single_edge": R.rule_extended_single_edge,
}


class Schedule(NamedTuple):
    """A rule schedule: which families run, in what order, and how often
    their test aggregates are refreshed ("rule" | "sweep")."""

    rules: Tuple[str, ...]
    refresh: str


#: The paper's §5.1 cheap-family order.
CHEAP_ORDER = (
    "degree_one",
    "neighborhood_removal",
    "weight_transfer",
    "simplicial",
    "basic_single_edge",
    "extended_single_edge",
)

#: Named schedules consumed by DisReduConfig.schedule.
SCHEDULES = {
    # seed per-rule semantics: every family sees fresh aggregates
    "cheap": Schedule(CHEAP_ORDER, "rule"),
    # fused hot path: aggregates snapshotted once per sweep (§Perf H3)
    "cheap-fused": Schedule(CHEAP_ORDER, "sweep"),
    # cheaper per-round schedules for reduce-and-greedy / reduce-and-peel:
    # no window/clique machinery at all (degree + neighborhood sums only)
    "light": Schedule(("degree_one", "neighborhood_removal"), "sweep"),
    # everything except the capped-window clique rules
    "edges-only": Schedule(
        ("degree_one", "neighborhood_removal", "basic_single_edge",
         "extended_single_edge"),
        "sweep",
    ),
}


def schedule_requires(schedule: Schedule) -> frozenset:
    """Union of the scheduled rules' aggregate declarations."""
    req = frozenset()
    for name in schedule.rules:
        req |= RULES[name].requires
    return req


# --------------------------------------------------------------------- #
# blocked-ELL plans (host-side packing of the static edge list)
# --------------------------------------------------------------------- #
class SegPlan(NamedTuple):
    """Precomputed blocked-ELL packing of one (static) row array.

    Built host-side once per Aux; the jitted sweep only gathers through it.
    ``rblk_tpl`` is a zero-size shape carrier so the (static) row-block
    height survives jit tracing without extra static arguments; ``wbits`` /
    ``wnh`` are the static per-edge window-position payloads that let the
    fused pass emit act_bits/clique (None when the plan was built without
    window structure).
    """

    edge_perm: jax.Array   # [n_blocks, E_BLK] i32 (stacked: [p, nb, E_BLK])
    lrow: jax.Array        # [n_blocks, E_BLK] i32
    rblk_tpl: jax.Array    # [r_blk, 0] i32 — zero-size static shape carrier
    wbits: Optional[jax.Array] = None  # [E] i32 window-position bits
    wnh: Optional[jax.Array] = None    # [E] i32 clique-violation masks

    @property
    def r_blk(self) -> int:
        return self.rblk_tpl.shape[0]


def autotune_r_blk(
    row: np.ndarray, n_rows: int,
    candidates: Tuple[int, ...] = R_BLK_CANDIDATES,
) -> int:
    """Pick the row-block height minimizing padded blocked-ELL traffic.

    The edge budget E_BLK is the max edge count over row blocks, so skewed
    degree distributions (GNM) blow up the padding at small R_BLK; larger
    blocks average the skew out.  Cost model = total padded items
    (n_blocks * E_BLK) — the HBM traffic this memory-bound op pays — with
    ties broken toward the smaller R_BLK (cheaper one-hot matmul).

    ``row`` may be stacked [p, E]: the cost then models the stacked
    packing's SHARED edge budget (max of the per-PE maxima), matching
    ``pack_blocks_stacked``.
    """
    rows = np.asarray(row)
    if rows.ndim == 1:
        rows = rows[None, :]
    best_r, best_cost = candidates[0], None
    for r in candidates:
        n_blocks = max((n_rows + r - 1) // r, 1)
        e_blk = max(
            int(np.bincount(rows[i] // r, minlength=n_blocks)
                .max(initial=1))
            for i in range(rows.shape[0])
        )
        e_blk = ((max(e_blk, 1) + E_BLK_MULTIPLE - 1) // E_BLK_MULTIPLE) \
            * E_BLK_MULTIPLE
        cost = n_blocks * e_blk
        if best_cost is None or cost < best_cost:
            best_r, best_cost = r, cost
    return best_r


def _window_payloads(
    row: np.ndarray, col: np.ndarray, gid: np.ndarray,
    window: np.ndarray, win_adj_bits: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """Static per-edge window payloads (host-side, once per partition).

    For edge (v, u): ``wbits`` ORs ``1 << i`` over every window position i
    of v holding u; ``wnh`` ORs the matching clique-violation masks
    ``~(win_adj_bits[v, i] | 1 << i)`` truncated to D bits (act_bits has no
    higher bits, so the truncation never changes ``act_bits & wnh``).
    Window entries are edge targets by construction (partition builds
    windows from the first D edges per row), so the OR over a vertex's
    edges recovers exactly the seed's window loop.
    """
    row = np.asarray(row, np.int64)
    col = np.asarray(col, np.int64)
    D = window.shape[1]
    if D >= 32:
        raise ValueError(f"window cap D={D} must fit int32 OR payloads")
    mask_d = np.int32((1 << D) - 1)
    ent = np.asarray(window, np.int64)[row]          # [E, D]
    adj = np.asarray(win_adj_bits, np.int32)[row]    # [E, D]
    gok = np.asarray(gid, np.int32)[col] >= 0
    wbits = np.zeros(row.shape[0], np.int32)
    wnh = np.zeros(row.shape[0], np.int32)
    for i in range(D):
        m = (ent[:, i] == col) & gok
        wbits |= m.astype(np.int32) << i
        wnh |= np.where(m, ~(adj[:, i] | np.int32(1 << i)) & mask_d, 0)
    return wbits, wnh


def build_plan(
    row: np.ndarray, n_rows: int, *, r_blk: Optional[int] = R_BLK,
    col: Optional[np.ndarray] = None, gid: Optional[np.ndarray] = None,
    window: Optional[np.ndarray] = None,
    win_adj_bits: Optional[np.ndarray] = None,
) -> SegPlan:
    """Pack one PE's (or the union graph's) row array.

    ``r_blk=None`` autotunes the row-block height (see
    :func:`autotune_r_blk`).  Passing the static window structure
    (col/gid/window/win_adj_bits) additionally packs the act_bits/clique
    payloads so the fused pass can emit the window bits.
    """
    if r_blk is None:
        r_blk = autotune_r_blk(np.asarray(row), n_rows)
    perm, lrow, _ = pack_blocks(
        np.asarray(row), n_rows, r_blk=r_blk, e_blk_multiple=E_BLK_MULTIPLE
    )
    wbits = wnh = None
    if window is not None:
        wb, wn = _window_payloads(row, col, gid, window, win_adj_bits)
        wbits, wnh = jnp.asarray(wb), jnp.asarray(wn)
    return SegPlan(
        edge_perm=jnp.asarray(perm, jnp.int32),
        lrow=jnp.asarray(lrow, jnp.int32),
        rblk_tpl=jnp.zeros((r_blk, 0), jnp.int32),
        wbits=wbits, wnh=wnh,
    )


def build_plan_stacked(
    rows: np.ndarray, n_rows: int, *, r_blk: Optional[int] = R_BLK,
    cols: Optional[np.ndarray] = None, gids: Optional[np.ndarray] = None,
    windows: Optional[np.ndarray] = None,
    win_adj_bits: Optional[np.ndarray] = None,
) -> SegPlan:
    """Stacked [p, ...] plan for the shard_map path (shared E_BLK).

    ``r_blk=None`` autotunes one shared height over all PEs' rows."""
    rows = np.asarray(rows)
    if r_blk is None:
        r_blk = autotune_r_blk(rows, n_rows)
    perm, lrow, _ = pack_blocks_stacked(
        rows, n_rows, r_blk=r_blk, e_blk_multiple=E_BLK_MULTIPLE
    )
    wbits = wnh = None
    if windows is not None:
        p = rows.shape[0]
        wb = np.zeros(rows.shape, np.int32)
        wn = np.zeros(rows.shape, np.int32)
        for i in range(p):
            wb[i], wn[i] = _window_payloads(
                rows[i], cols[i], gids[i], windows[i], win_adj_bits[i]
            )
        wbits, wnh = jnp.asarray(wb), jnp.asarray(wn)
    return SegPlan(
        edge_perm=jnp.asarray(perm, jnp.int32),
        lrow=jnp.asarray(lrow, jnp.int32),
        rblk_tpl=jnp.zeros((r_blk, 0), jnp.int32),
        wbits=wbits, wnh=wnh,
    )


# --------------------------------------------------------------------- #
# topology-keyed plan caching (the serving layer's reuse contract)
# --------------------------------------------------------------------- #
def topology_hash(row: np.ndarray, col: np.ndarray, n_rows: int) -> str:
    """Digest of the (sorted) directed edge list — weights excluded.

    Two instances share a hash iff they have the same vertex budget and the
    same edge set, which is exactly the condition under which every
    topology-derived artifact (blocked-ELL :class:`SegPlan`, window
    payloads, halo routing) is reusable verbatim; only the weight vector
    differs between requests.  The pairs are lexsorted before hashing so
    any permutation of the same edge multiset maps to one key.
    """
    row = np.ascontiguousarray(row, dtype=np.int64).reshape(-1)
    col = np.ascontiguousarray(col, dtype=np.int64).reshape(-1)
    order = np.lexsort((col, row))
    h = hashlib.sha1()
    h.update(np.int64(n_rows).tobytes())
    h.update(row[order].tobytes())
    h.update(col[order].tobytes())
    return h.hexdigest()


class PlanCacheStats(NamedTuple):
    hits: int
    misses: int
    evictions: int
    size: int
    errors: int = 0        # build() raises observed by get_or_build
    descent_hits: int = 0    # tag="descent" lookups served from cache
    descent_misses: int = 0  # tag="descent" lookups that (re)built


class PlanCache:
    """Bounded LRU cache for topology-keyed artifacts (SegPlans, packed
    serve entries).  Host-side and not thread-safe — one cache per service
    / driver.  ``max_entries`` bounds resident plans (ISSUE: eviction bound
    respected); hits refresh recency."""

    def __init__(self, max_entries: int = 256):
        if max_entries < 1:
            raise ValueError("PlanCache needs max_entries >= 1")
        self.max_entries = max_entries
        self._d: OrderedDict = OrderedDict()
        self._hits = self._misses = self._evictions = self._errors = 0
        self._descent_hits = self._descent_misses = 0

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:
        return key in self._d

    def get(self, key, tag: Optional[str] = None):
        """Value for `key` (refreshing recency) or None on miss.

        ``tag="descent"`` additionally counts the lookup in the descent
        hit/miss counters (stats telemetry for mid-solve re-packs); the
        cache contents are tag-agnostic, so a plan built by the fixed-shape
        path is a hit for a descent lookup of the same topology.
        """
        if key in self._d:
            self._d.move_to_end(key)
            self._hits += 1
            if tag == "descent":
                self._descent_hits += 1
            return self._d[key]
        self._misses += 1
        if tag == "descent":
            self._descent_misses += 1
        return None

    def put(self, key, value) -> None:
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = value
        while len(self._d) > self.max_entries:
            self._d.popitem(last=False)
            self._evictions += 1

    def get_or_build(self, key, build, tag: Optional[str] = None):
        """Cached value for `key`, calling `build()` (and caching) on miss.

        A raising ``build()`` leaves the cache **unpoisoned**: no entry is
        inserted for `key` (a later call re-attempts the build), the miss
        is counted exactly once, the failure is counted in
        ``stats.errors``, and the exception propagates to the caller.
        """
        val = self.get(key, tag=tag)
        if val is None:
            try:
                val = build()
            except Exception:
                self._errors += 1
                raise
            self.put(key, val)
        return val

    @property
    def stats(self) -> PlanCacheStats:
        return PlanCacheStats(
            hits=self._hits, misses=self._misses,
            evictions=self._evictions, size=len(self._d),
            errors=self._errors,
            descent_hits=self._descent_hits,
            descent_misses=self._descent_misses,
        )


def plan_for(
    cache: Optional[PlanCache],
    row: np.ndarray, n_rows: int, *, r_blk: Optional[int] = R_BLK,
    col: Optional[np.ndarray] = None, gid: Optional[np.ndarray] = None,
    window: Optional[np.ndarray] = None,
    win_adj_bits: Optional[np.ndarray] = None,
    tag: Optional[str] = None,
) -> SegPlan:
    """:func:`build_plan` through a :class:`PlanCache` keyed by topology
    hash (plus the static build knobs).  ``cache=None`` builds uncached.
    ``tag="descent"`` marks the lookup in the cache's descent counters
    (shape-descent re-packs share the same key space as cold packs)."""
    if cache is None:
        return build_plan(
            row, n_rows, r_blk=r_blk, col=col, gid=gid, window=window,
            win_adj_bits=win_adj_bits,
        )
    key = (
        topology_hash(row, col if col is not None else row, n_rows),
        r_blk, window is not None,
    )
    return cache.get_or_build(key, lambda: build_plan(
        row, n_rows, r_blk=r_blk, col=col, gid=gid, window=window,
        win_adj_bits=win_adj_bits,
    ), tag=tag)


# --------------------------------------------------------------------- #
# batched plans (serving layer: one vmapped pass over many instances)
# --------------------------------------------------------------------- #
def pad_plan(plan: SegPlan, e_blk: int) -> SegPlan:
    """Pad a plan's edge budget up to `e_blk` so same-cell plans stack.

    Padding slots follow the :func:`pack_blocks` convention — edge 0 with
    ``lrow = r_blk`` — which every blocked kernel ignores, so a padded plan
    is bit-identical in effect to the original.
    """
    nb, eb = plan.edge_perm.shape
    if eb > e_blk:
        raise ValueError(f"cannot shrink plan E_BLK {eb} -> {e_blk}")
    if eb == e_blk:
        return plan
    perm = jnp.zeros((nb, e_blk), jnp.int32).at[:, :eb].set(plan.edge_perm)
    lrow = jnp.full((nb, e_blk), plan.r_blk, jnp.int32).at[:, :eb].set(
        plan.lrow
    )
    return plan._replace(edge_perm=perm, lrow=lrow)


def stack_plans(plans: Sequence[SegPlan],
                e_blk: Optional[int] = None,
                batch_multiple: int = 1) -> SegPlan:
    """Stack same-cell plans onto a leading batch axis (shared E_BLK).

    All plans must share ``r_blk`` and row count (same serve cell); each is
    padded to the common edge budget — `e_blk` if given (a high-water mark
    keeps recompiles monotone in the serving layer), else the batch max.
    Window payloads must be uniformly present or absent.

    ``batch_multiple`` pads the batch axis up to a multiple of the given
    count by repeating the LAST plan (phantom instances, matching the
    serving layer's repeat-last request padding) so the stacked plan
    splits evenly across a device mesh; phantom slots are sliced off by
    the caller, never read back.
    """
    if not plans:
        raise ValueError("stack_plans needs at least one plan")
    if batch_multiple < 1:
        raise ValueError(f"batch_multiple must be >= 1, got {batch_multiple}")
    if len(plans) % batch_multiple:
        pad = batch_multiple - len(plans) % batch_multiple
        plans = list(plans) + [plans[-1]] * pad
    r_blk = plans[0].r_blk
    nb = plans[0].edge_perm.shape[0]
    if any(p.r_blk != r_blk or p.edge_perm.shape[0] != nb for p in plans):
        raise ValueError("stack_plans needs plans from one serve cell "
                         "(same r_blk and row-block count)")
    has_w = [p.wbits is not None for p in plans]
    if any(h != has_w[0] for h in has_w):
        raise ValueError("mixed window payloads across batch plans")
    need = max(p.edge_perm.shape[1] for p in plans)
    if e_blk is None:
        e_blk = need
    elif e_blk < need:
        raise ValueError(f"e_blk={e_blk} below batch requirement {need}")
    padded = [pad_plan(p, e_blk) for p in plans]
    return SegPlan(
        edge_perm=jnp.stack([p.edge_perm for p in padded]),
        lrow=jnp.stack([p.lrow for p in padded]),
        rblk_tpl=jnp.zeros((len(plans), r_blk, 0), jnp.int32),
        wbits=jnp.stack([p.wbits for p in padded]) if has_w[0] else None,
        wnh=jnp.stack([p.wnh for p in padded]) if has_w[0] else None,
    )


def aggregate_batched(
    seg: Optional[jax.Array],
    n_rows: int,
    *,
    data_sum: Optional[jax.Array] = None,
    data_max: Optional[jax.Array] = None,
    data_min: Optional[jax.Array] = None,
    data_or: Optional[jax.Array] = None,
    or_nbits: int = 16,
    backend: str = "jnp",
    plan: Optional[SegPlan] = None,
    indices_are_sorted: bool = True,
) -> Tuple[Optional[jax.Array], ...]:
    """:func:`aggregate` vmapped over a leading batch axis.

    Payloads (and ``seg`` / the plan leaves, when present) carry a leading
    batch dimension; every instance is reduced independently and the
    outputs come back ``[batch, n_rows, ...]``.  Bit-identical per instance
    to the unbatched entry point on every backend — vmap only reshapes the
    integer ops, it never reassociates them.
    """
    def one(seg_i, d_sum, d_max, d_min, d_or, plan_i):
        return aggregate(
            seg_i, n_rows, data_sum=d_sum, data_max=d_max, data_min=d_min,
            data_or=d_or, or_nbits=or_nbits, backend=backend, plan=plan_i,
            indices_are_sorted=indices_are_sorted,
        )
    axes = (
        None if seg is None else 0,
        None if data_sum is None else 0,
        None if data_max is None else 0,
        None if data_min is None else 0,
        None if data_or is None else 0,
        None if plan is None else SegPlan(
            edge_perm=0, lrow=0, rblk_tpl=0,
            wbits=None if plan.wbits is None else 0,
            wnh=None if plan.wnh is None else 0,
        ),
    )
    return jax.vmap(one, in_axes=axes)(
        seg, data_sum, data_max, data_min, data_or, plan
    )


# --------------------------------------------------------------------- #
# the one segment-reduction entry point (backend dispatch)
# --------------------------------------------------------------------- #
def aggregate(
    seg: Optional[jax.Array],
    n_rows: int,
    *,
    data_sum: Optional[jax.Array] = None,
    data_max: Optional[jax.Array] = None,
    data_min: Optional[jax.Array] = None,
    data_or: Optional[jax.Array] = None,
    or_nbits: int = 16,
    backend: str = "jnp",
    plan: Optional[SegPlan] = None,
    indices_are_sorted: bool = True,
) -> Tuple[Optional[jax.Array], ...]:
    """Segment-reduce edge payloads to [n_rows] outputs on one backend.

    Returns a ``(sum, max, min, or)`` tuple (None for absent groups); 1-D
    payloads come back 1-D.  ``seg`` is the per-item segment id array,
    needed by the jnp backend only (the blocked backends traverse through
    the precomputed ``plan``; pass the plan's own row array as ``seg`` when
    both may run).  ``num_segments`` is always the static ``n_rows`` —
    every call site passes a Python int, so round-to-round shapes never
    recompile.  ``indices_are_sorted`` defaults to True because every Aux
    row array is sorted by partition construction (lexsort + nil-padding at
    the top index; offsets keep the union concatenation sorted) — pass
    False when reducing over anything else.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown aggregate backend {backend!r}")
    groups = [data_sum, data_max, data_min, data_or]
    if all(d is None for d in groups):
        raise ValueError("aggregate needs at least one payload group")

    squeeze = [d is not None and d.ndim == 1 for d in groups]
    groups = [d[:, None] if d is not None and d.ndim == 1 else d
              for d in groups]
    d_sum, d_max, d_min, d_or = groups

    if backend == "jnp":
        if seg is None:
            raise ValueError("backend 'jnp' needs the segment id array")
        kw = dict(num_segments=n_rows, indices_are_sorted=indices_are_sorted)
        outs = (
            segment_sum(d_sum, seg, **kw) if d_sum is not None else None,
            segment_max(d_max, seg, **kw) if d_max is not None else None,
            segment_min(d_min, seg, **kw) if d_min is not None else None,
            segment_or_ref(
                d_or, seg, n_rows, nbits=or_nbits,
                indices_are_sorted=indices_are_sorted,
            ) if d_or is not None else None,
        )
    else:
        if plan is None:
            raise ValueError(f"backend {backend!r} needs a SegPlan")
        outs = segment_fused_coo(
            plan.edge_perm, plan.lrow, n_rows,
            data_sum=d_sum, data_max=d_max, data_min=d_min, data_or=d_or,
            or_nbits=or_nbits, r_blk=plan.r_blk,
            force_pallas=(backend == "pallas"),
        )
    return tuple(
        o[:, 0] if o is not None and sq else o
        for o, sq in zip(outs, squeeze)
    )


# --------------------------------------------------------------------- #
# aggregate computation (SweepCtx for the scheduled rules)
# --------------------------------------------------------------------- #
def compute_ctx(
    state: R.RedState,
    aux: R.Aux,
    requires: frozenset,
    *,
    backend: str = "jnp",
    plan: Optional[SegPlan] = None,
) -> R.SweepCtx:
    """Compute exactly the requested aggregates into a SweepCtx.

    `requires` and `backend` are trace-static; `plan` is a traced pytree
    (None for the jnp backend).  On the blocked/pallas backends everything —
    edge sums/maxes AND the window activity/clique bits — comes out of ONE
    fused pass over the packed edge blocks.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown aggregate backend {backend!r}")
    if backend != "jnp" and plan is None:
        raise ValueError(f"backend {backend!r} needs a SegPlan (got None)")
    V = state.w.shape[0]
    D = aux.window.shape[1]
    active = R._active(state)
    eact = R._edge_active(aux, active)
    S = deg = M = only = act_bits = clique = None

    edge_req = requires & {"S", "deg", "M", "only"}
    need_bits = bool(requires & {"act_bits", "clique"})
    payload = {
        "S": lambda: jnp.where(eact, R._aw(state, active)[aux.col], 0),
        "deg": lambda: eact.astype(jnp.int32),
        "M": lambda: jnp.where(eact, state.w[aux.col], I32_MIN),
        "only": lambda: jnp.where(eact, aux.col, -1),
    }
    sum_fields = [f for f in ("S", "deg") if f in edge_req]
    max_fields = [f for f in ("M", "only") if f in edge_req]
    data_sum = (
        jnp.stack([payload[f]() for f in sum_fields], axis=1)
        if sum_fields else None
    )
    data_max = (
        jnp.stack([payload[f]() for f in max_fields], axis=1)
        if max_fields else None
    )

    data_or = None
    if need_bits and backend != "jnp":
        if plan.wbits is None:
            raise ValueError(
                "plan lacks window payloads; build it with the window "
                "structure (col/gid/window/win_adj_bits) to compute "
                "act_bits/clique on the blocked backends"
            )
        acol = active[aux.col]
        data_or = jnp.where(
            acol[:, None], jnp.stack([plan.wbits, plan.wnh], axis=1), 0
        )

    sums = maxs = ors = None
    if data_sum is not None or data_max is not None or data_or is not None:
        sums, maxs, _, ors = aggregate(
            aux.row, V, data_sum=data_sum, data_max=data_max,
            data_or=data_or, or_nbits=max(D, 1), backend=backend, plan=plan,
        )
    out = {}
    for i, f in enumerate(sum_fields):
        out[f] = sums[:, i]
    for i, f in enumerate(max_fields):
        out[f] = maxs[:, i]
    S, deg = out.get("S"), out.get("deg")
    if "M" in out:
        M = jnp.maximum(out["M"], I32_MIN)
    if "only" in out:
        only = jnp.maximum(out["only"], 0)

    if need_bits:
        if backend == "jnp":
            act_bits = W.window_active_bits(active, aux.gid, aux.window)
            if "clique" in requires:
                clique = W.window_clique_ok(act_bits, aux.win_adj_bits)
        else:
            act_bits = ors[:, 0]
            if "clique" in requires:
                clique = (act_bits & ors[:, 1]) == 0
    if "act_bits" not in requires:
        act_bits = None
    return R.SweepCtx(
        S=S, deg=deg, M=M, only=only, act_bits=act_bits, clique=clique
    )


# --------------------------------------------------------------------- #
# sweep driver
# --------------------------------------------------------------------- #
def sweep(
    state: R.RedState,
    aux: R.Aux,
    *,
    schedule: str = "cheap",
    backend: str = "jnp",
    plan: Optional[SegPlan] = None,
) -> R.RedState:
    """One pass of the scheduled rule families.

    refresh="sweep": the union of the schedule's aggregate requirements is
    computed ONCE and shared by every family (tests conservatively stale,
    applications fresh).  refresh="rule": each family gets its declared
    aggregates recomputed at rule entry (seed per-rule semantics).
    """
    if schedule not in SCHEDULES:
        raise ValueError(
            f"unknown rule schedule {schedule!r}; "
            f"available: {sorted(SCHEDULES)}"
        )
    sched = SCHEDULES[schedule]
    if sched.refresh == "sweep":
        ctx = compute_ctx(
            state, aux, schedule_requires(sched), backend=backend, plan=plan
        )
        for name in sched.rules:
            state = RULES[name](state, aux, ctx)
    else:
        for name in sched.rules:
            ctx = compute_ctx(
                state, aux, RULES[name].requires, backend=backend, plan=plan
            )
            state = RULES[name](state, aux, ctx)
    return state
