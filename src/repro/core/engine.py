"""Aggregate engine — one pluggable backend for every rule-test aggregate.

The paper's reduction rules "act very locally": every rule *test* is a
bounded neighborhood aggregate (sum / max over the masked edge list, plus
capped-window clique bits).  Rule portfolios keep growing (Großmann et al.'s
rule survey, the KaMIS reduce-and-peel line), which is only sustainable if
rules *declare* the aggregates they need and a single engine computes them —
once per sweep, on the fastest available backend — instead of every rule
family issuing its own ad-hoc segment reductions.

Three pieces:

  * **declarations** — each rule in :mod:`repro.core.rules` carries a
    ``requires`` frozenset (``@_requires``) naming the :class:`SweepCtx`
    fields its test reads.  The engine computes exactly the union of the
    scheduled rules' requirements; undeclared fields stay ``None``.
  * **schedules** — the rule order is data, not code: a named
    :class:`Schedule` lists the rule families to run and the aggregate
    *refresh* granularity:

      - ``refresh="rule"``  — aggregates recomputed before every rule
        (the seed PR's exact per-rule semantics; parity oracle in
        ``tests/seed_oracle.py``),
      - ``refresh="sweep"`` — aggregates snapshotted ONCE per sweep and
        shared by all families (the fused hot path; tests go conservatively
        stale, applications stay fresh — see the SweepCtx docstring and
        ARCHITECTURE.md for the soundness argument).

  * **backends** — the segment reductions behind the aggregates dispatch
    through one of:

      - ``"jnp"``     — ``jax.ops.segment_*`` (portable; XLA sort-based),
      - ``"blocked"`` — blocked-ELL layout via the precomputed
        :class:`SegPlan` packing, jnp per-block reference kernels,
      - ``"pallas"``  — the same blocked-ELL layout through the fused
        multi-payload Pallas kernel (`kernels/segment_coo`), one pass over
        the packed edge blocks for all sum+max payloads (interpret mode off
        TPU).

    All payloads are int32, and integer addition is associative, so all
    three backends are **bit-identical** — backend choice is purely a
    performance decision.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.ops import segment_max

from repro.core import rules as R
from repro.kernels.segment_coo.ops import (
    pack_blocks, pack_blocks_stacked, segment_fused_coo,
)

I32_MIN = jnp.iinfo(jnp.int32).min

#: SweepCtx fields a rule may declare via @_requires (validated there).
AGGREGATES = R.SweepCtx._fields

#: Aggregate backends (see module docstring).
BACKENDS = ("jnp", "blocked", "pallas")

#: Row-block height of the blocked-ELL packing (sublane-aligned).
R_BLK = 8

#: Rule registry: schedule entries name rules; order comes from Schedule.
RULES = {
    "degree_one": R.rule_degree_one,
    "neighborhood_removal": R.rule_neighborhood_removal,
    "weight_transfer": R.rule_weight_transfer,
    "simplicial": R.rule_simplicial,
    "basic_single_edge": R.rule_basic_single_edge,
    "extended_single_edge": R.rule_extended_single_edge,
}


class Schedule(NamedTuple):
    """A rule schedule: which families run, in what order, and how often
    their test aggregates are refreshed ("rule" | "sweep")."""

    rules: Tuple[str, ...]
    refresh: str


#: The paper's §5.1 cheap-family order.
CHEAP_ORDER = (
    "degree_one",
    "neighborhood_removal",
    "weight_transfer",
    "simplicial",
    "basic_single_edge",
    "extended_single_edge",
)

#: Named schedules consumed by DisReduConfig.schedule.
SCHEDULES = {
    # seed per-rule semantics: every family sees fresh aggregates
    "cheap": Schedule(CHEAP_ORDER, "rule"),
    # fused hot path: aggregates snapshotted once per sweep (§Perf H3)
    "cheap-fused": Schedule(CHEAP_ORDER, "sweep"),
    # cheaper per-round schedules for reduce-and-greedy / reduce-and-peel:
    # no window/clique machinery at all (degree + neighborhood sums only)
    "light": Schedule(("degree_one", "neighborhood_removal"), "sweep"),
    # everything except the capped-window clique rules
    "edges-only": Schedule(
        ("degree_one", "neighborhood_removal", "basic_single_edge",
         "extended_single_edge"),
        "sweep",
    ),
}


def schedule_requires(schedule: Schedule) -> frozenset:
    """Union of the scheduled rules' aggregate declarations."""
    req = frozenset()
    for name in schedule.rules:
        req |= RULES[name].requires
    return req


# --------------------------------------------------------------------- #
# blocked-ELL plans (host-side packing of the static edge list)
# --------------------------------------------------------------------- #
class SegPlan(NamedTuple):
    """Precomputed blocked-ELL packing of one (static) row array.

    Built host-side once per Aux; the jitted sweep only gathers through it.
    """

    edge_perm: jax.Array   # [n_blocks, E_BLK] i32 (stacked: [p, nb, E_BLK])
    lrow: jax.Array        # [n_blocks, E_BLK] i32


def build_plan(row: np.ndarray, n_rows: int, *, r_blk: int = R_BLK) -> SegPlan:
    """Pack one PE's (or the union graph's) row array."""
    perm, lrow, _ = pack_blocks(np.asarray(row), n_rows, r_blk=r_blk)
    return SegPlan(
        edge_perm=jnp.asarray(perm, jnp.int32),
        lrow=jnp.asarray(lrow, jnp.int32),
    )


def build_plan_stacked(
    rows: np.ndarray, n_rows: int, *, r_blk: int = R_BLK,
) -> SegPlan:
    """Stacked [p, ...] plan for the shard_map path (shared E_BLK)."""
    perm, lrow, _ = pack_blocks_stacked(
        np.asarray(rows), n_rows, r_blk=r_blk
    )
    return SegPlan(
        edge_perm=jnp.asarray(perm, jnp.int32),
        lrow=jnp.asarray(lrow, jnp.int32),
    )


# --------------------------------------------------------------------- #
# aggregate computation (the backend dispatch)
# --------------------------------------------------------------------- #
def compute_ctx(
    state: R.RedState,
    aux: R.Aux,
    requires: frozenset,
    *,
    backend: str = "jnp",
    plan: Optional[SegPlan] = None,
) -> R.SweepCtx:
    """Compute exactly the requested aggregates into a SweepCtx.

    `requires` and `backend` are trace-static; `plan` is a traced pytree
    (None for the jnp backend).
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown aggregate backend {backend!r}")
    if backend != "jnp" and plan is None:
        raise ValueError(f"backend {backend!r} needs a SegPlan (got None)")
    V = state.w.shape[0]
    active = R._active(state)
    eact = R._edge_active(aux, active)
    S = deg = M = only = act_bits = clique = None

    edge_req = requires & {"S", "deg", "M", "only"}
    if edge_req and backend == "jnp":
        if "S" in edge_req:
            S = R._nbr_sum(aux, eact, R._aw(state, active), V)
        if "deg" in edge_req:
            deg = R._act_deg(aux, eact, V)
        if "M" in edge_req:
            M = R._nbr_max(aux, eact, state.w, V)
        if "only" in edge_req:
            only = jnp.maximum(
                segment_max(
                    jnp.where(eact, aux.col, -1), aux.row, num_segments=V
                ),
                0,
            )
    elif edge_req:
        # blocked-ELL: ONE fused pass over the packed edge blocks computes
        # every sum and max payload together (int32 => bit-identical to jnp)
        sum_fields = [f for f in ("S", "deg") if f in edge_req]
        max_fields = [f for f in ("M", "only") if f in edge_req]
        payload = {
            "S": lambda: jnp.where(eact, R._aw(state, active)[aux.col], 0),
            "deg": lambda: eact.astype(jnp.int32),
            "M": lambda: jnp.where(eact, state.w[aux.col], I32_MIN),
            "only": lambda: jnp.where(eact, aux.col, -1),
        }
        data_sum = (
            jnp.stack([payload[f]() for f in sum_fields], axis=1)
            if sum_fields else None
        )
        data_max = (
            jnp.stack([payload[f]() for f in max_fields], axis=1)
            if max_fields else None
        )
        sums, maxs, _ = segment_fused_coo(
            plan.edge_perm, plan.lrow, V,
            data_sum=data_sum, data_max=data_max,
            r_blk=R_BLK, force_pallas=(backend == "pallas"),
        )
        out = {}
        for i, f in enumerate(sum_fields):
            out[f] = sums[:, i]
        for i, f in enumerate(max_fields):
            out[f] = maxs[:, i]
        S, deg = out.get("S"), out.get("deg")
        if "M" in out:
            M = jnp.maximum(out["M"], I32_MIN)
        if "only" in out:
            only = jnp.maximum(out["only"], 0)

    if "act_bits" in requires or "clique" in requires:
        act_bits = R._window_active_bits(state, aux)
    if "clique" in requires:
        clique = R._is_clique(state, aux, act_bits)
    if "act_bits" not in requires:
        act_bits = None
    return R.SweepCtx(
        S=S, deg=deg, M=M, only=only, act_bits=act_bits, clique=clique
    )


# --------------------------------------------------------------------- #
# sweep driver
# --------------------------------------------------------------------- #
def sweep(
    state: R.RedState,
    aux: R.Aux,
    *,
    schedule: str = "cheap",
    backend: str = "jnp",
    plan: Optional[SegPlan] = None,
) -> R.RedState:
    """One pass of the scheduled rule families.

    refresh="sweep": the union of the schedule's aggregate requirements is
    computed ONCE and shared by every family (tests conservatively stale,
    applications fresh).  refresh="rule": each family gets its declared
    aggregates recomputed at rule entry (seed per-rule semantics).
    """
    if schedule not in SCHEDULES:
        raise ValueError(
            f"unknown rule schedule {schedule!r}; "
            f"available: {sorted(SCHEDULES)}"
        )
    sched = SCHEDULES[schedule]
    if sched.refresh == "sweep":
        ctx = compute_ctx(
            state, aux, schedule_requires(sched), backend=backend, plan=plan
        )
        for name in sched.rules:
            state = RULES[name](state, aux, ctx)
    else:
        for name in sched.rules:
            ctx = compute_ctx(
                state, aux, RULES[name].requires, backend=backend, plan=plan
            )
            state = RULES[name](state, aux, ctx)
    return state
