"""Sequential reduce / reduce-and-peel baseline (HtWIS-style, numpy/python).

This is the repo's stand-in for the paper's sequential baseline HtWIS
(Gu et al. [25]) and simultaneously the *reference semantics* for every
reduction rule the distributed JAX path implements.  It runs the full rule
set of §5.1 — including the folding rules (V-Shape merge, Neighborhood
Folding) that the distributed reduction model cannot express (no new cut
edges / static shapes) — so comparing kernels quantifies exactly what the
border restrictions cost, mirroring the paper's own sequential-vs-p
comparison (Fig. 7.1).

Rule order follows §5.1:
  degree-zero/one → neighborhood removal → simplicial weight transfer →
  simplicial vertex → V-shape (deg-2 cases of neighborhood folding) →
  basic single-edge → extended single-edge → neighborhood folding →
  heavy vertex (exact sub-MWIS, subproblem capped at `heavy_cap` = 10,
  the paper's cap).

Everything is exact integer arithmetic.  Reconstruction replays the fold
log in reverse; `solve()` returns a verified independent set.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Set, Tuple

import numpy as np

from repro.core.bitset_mwis import alpha_subset
from repro.core.graph import Graph

UNDECIDED, INCLUDED, EXCLUDED, FOLDED = 0, 1, 2, 3


@dataclasses.dataclass
class SeqConfig:
    heavy_cap: int = 10        # max |N(v)| for the exact sub-MWIS (paper: 10)
    simplicial_cap: int = 32   # max degree for clique tests
    fold_cap: int = 8          # max |N(v)| for neighborhood folding
    use_folding: bool = True   # V-shape merge + neighborhood folding
    use_single_edge: bool = True
    use_heavy: bool = True
    max_rounds: int = 10_000_000


class SequentialReducer:
    """Mutable reduction engine over adjacency sets."""

    def __init__(self, g: Graph, cfg: Optional[SeqConfig] = None):
        self.cfg = cfg or SeqConfig()
        self.g = g
        n = g.n
        self.adj: List[Set[int]] = [set(g.neighbors(v).tolist()) for v in range(n)]
        self.w: List[int] = g.weights.astype(np.int64).tolist()
        self.status: List[int] = [UNDECIDED] * n
        self.offset = 0
        # log entries: ("fold1", v, u) | ("wt", v, nbrs) | ("nf", v, nbrs, vp)
        self.log: List[tuple] = []
        self.n_orig = n

    # ----------------------------------------------------------------- #
    # primitive mutations
    # ----------------------------------------------------------------- #
    def _detach(self, v: int) -> None:
        for u in self.adj[v]:
            self.adj[u].discard(v)
        self.adj[v] = set()

    def include(self, v: int) -> None:
        assert self.status[v] == UNDECIDED
        self.status[v] = INCLUDED
        for u in list(self.adj[v]):
            if self.status[u] == UNDECIDED:
                self.exclude(u)
        self._detach(v)

    def exclude(self, v: int) -> None:
        assert self.status[v] == UNDECIDED
        self.status[v] = EXCLUDED
        self._detach(v)

    def alive(self, v: int) -> bool:
        return self.status[v] == UNDECIDED

    def alive_vertices(self) -> List[int]:
        return [v for v in range(len(self.w)) if self.status[v] == UNDECIDED]

    def nbr_weight(self, v: int) -> int:
        return sum(self.w[u] for u in self.adj[v])

    # ----------------------------------------------------------------- #
    # rules — each returns True if it changed the graph at v
    # ----------------------------------------------------------------- #
    def _rule_low_degree(self, v: int) -> bool:
        deg = len(self.adj[v])
        if deg == 0:
            self.include(v)
            return True
        if deg == 1:
            (u,) = self.adj[v]
            if self.w[v] >= self.w[u]:
                self.include(v)
            else:
                # degree-one fold (Chang/Gu): w(u) -= w(v); v in I iff u not.
                self.w[u] -= self.w[v]
                self.offset += self.w[v]
                self.status[v] = FOLDED
                self._detach(v)
                self.log.append(("fold1", v, u))
            return True
        return False

    def _rule_neighborhood_removal(self, v: int) -> bool:
        if self.w[v] >= self.nbr_weight(v):
            self.include(v)
            return True
        return False

    def _is_simplicial(self, v: int) -> bool:
        nbrs = list(self.adj[v])
        if len(nbrs) > self.cfg.simplicial_cap:
            return False
        for i, a in enumerate(nbrs):
            for b in nbrs[i + 1:]:
                if b not in self.adj[a]:
                    return False
        return True

    def _rule_simplicial(self, v: int) -> bool:
        if not self._is_simplicial(v):
            return False
        nbrs = list(self.adj[v])
        mx = max((self.w[u] for u in nbrs), default=0)
        if self.w[v] >= mx:
            self.include(v)
            return True
        # Simplicial weight transfer (Reduction 4.5): v must be max-weight
        # among the simplicial vertices of its neighborhood (paper: S(v)).
        if any(
            self.w[u] > self.w[v] and self._is_simplicial(u) for u in nbrs
        ):
            return False
        wv = self.w[v]
        removed = [u for u in nbrs if self.w[u] <= wv]
        survivors = [u for u in nbrs if self.w[u] > wv]
        self.log.append(("wt", v, tuple(nbrs)))
        self.status[v] = FOLDED
        self._detach(v)
        for u in removed:
            if self.status[u] == UNDECIDED:
                self.exclude(u)
        for u in survivors:
            self.w[u] -= wv
        self.offset += wv
        return True

    def _rule_basic_single_edge(self, v: int) -> bool:
        # exclude v if some neighbor u has w(u) >= w(N(u) \ N(v)).
        for u in self.adj[v]:
            s = sum(self.w[x] for x in self.adj[u] if x not in self.adj[v])
            # v itself is in N(u) \ N(v)  (v not adjacent to itself).
            if s <= self.w[u]:
                self.exclude(v)
                return True
        return False

    def _rule_extended_single_edge(self, v: int) -> bool:
        sv = self.nbr_weight(v)
        changed = False
        for u in list(self.adj[v]):
            if sv - self.w[u] <= self.w[v]:
                common = self.adj[v] & self.adj[u]
                for x in list(common):
                    if self.status[x] == UNDECIDED:
                        self.exclude(x)
                        changed = True
                sv = self.nbr_weight(v)
        return changed

    def _rule_neighborhood_fold(self, v: int) -> bool:
        nbrs = list(self.adj[v])
        if not (2 <= len(nbrs) <= self.cfg.fold_cap):
            return False
        # N(v) must be independent.
        for i, a in enumerate(nbrs):
            for b in nbrs[i + 1:]:
                if b in self.adj[a]:
                    return False
        s = sum(self.w[u] for u in nbrs)
        mn = min(self.w[u] for u in nbrs)
        if not (self.w[v] < s and self.w[v] >= s - mn):
            return False
        # Fold N[v] into a fresh vertex v' with w(v') = w(N(v)) - w(v).
        vp = len(self.w)
        self.w.append(s - self.w[v])
        self.status.append(UNDECIDED)
        new_nbrs: Set[int] = set()
        for u in nbrs:
            new_nbrs |= self.adj[u]
        new_nbrs -= set(nbrs)
        new_nbrs.discard(v)
        self.adj.append(set(new_nbrs))
        for x in new_nbrs:
            self.adj[x].add(vp)
        self.log.append(("nf", v, tuple(nbrs), vp))
        self.status[v] = FOLDED
        self._detach(v)
        for u in nbrs:
            self.status[u] = FOLDED
            self._detach(u)
        self.offset += self.w[v]
        return True

    def _rule_heavy_vertex(self, v: int) -> bool:
        nbrs = list(self.adj[v])
        if len(nbrs) > self.cfg.heavy_cap:
            return False
        k = len(nbrs)
        pos = {u: i for i, u in enumerate(nbrs)}
        bits = np.zeros(k, dtype=np.int64)
        for i, a in enumerate(nbrs):
            for b in self.adj[a]:
                j = pos.get(b)
                if j is not None:
                    bits[i] |= 1 << j
        alpha = alpha_subset(
            np.array([self.w[u] for u in nbrs], dtype=np.int64), bits
        )
        if self.w[v] >= alpha:
            self.include(v)
            return True
        return False

    # ----------------------------------------------------------------- #
    # driver
    # ----------------------------------------------------------------- #
    def reduce(self) -> None:
        """Exhaustively apply rules in the paper's §5.1 order (worklist)."""
        cfg = self.cfg
        pending = set(v for v in range(len(self.w)) if self.alive(v))
        rounds = 0
        while pending and rounds < cfg.max_rounds:
            rounds += 1
            v = pending.pop()
            if not self.alive(v):
                continue
            before_nbrs = set(self.adj[v])
            fired = (
                self._rule_low_degree(v)
                or self._rule_neighborhood_removal(v)
                or self._rule_simplicial(v)
                or (cfg.use_folding and self._rule_neighborhood_fold(v))
                or (cfg.use_single_edge and self._rule_basic_single_edge(v))
                or (cfg.use_single_edge and self._rule_extended_single_edge(v))
                or (cfg.use_heavy and self._rule_heavy_vertex(v))
            )
            if fired:
                # requeue the old neighborhood and its surroundings
                for u in before_nbrs:
                    if self.alive(u):
                        pending.add(u)
                        pending.update(
                            x for x in self.adj[u] if self.alive(x)
                        )
                if self.log and self.log[-1][0] == "nf":
                    vp = self.log[-1][3]
                    if self.alive(vp):
                        pending.add(vp)
                        pending.update(
                            x for x in self.adj[vp] if self.alive(x)
                        )

    # ----------------------------------------------------------------- #
    # peeling + reconstruction
    # ----------------------------------------------------------------- #
    def peel_one(self) -> Optional[int]:
        """Exclude argmax_v  w(N(v)) - w(v)  (HtWIS §6 peel criterion)."""
        best_v, best_score = None, None
        for v in range(len(self.w)):
            if self.alive(v):
                score = self.nbr_weight(v) - self.w[v]
                if best_score is None or score > best_score:
                    best_v, best_score = v, score
        if best_v is None:
            return None
        self.exclude(best_v)
        return best_v

    def reconstruct(self) -> np.ndarray:
        """Replay the fold log; returns bool member mask over ORIGINAL ids."""
        in_set = [s == INCLUDED for s in self.status]
        for rec in reversed(self.log):
            if rec[0] == "fold1":
                _, v, u = rec
                in_set[v] = not in_set[u]
            elif rec[0] == "wt":
                _, v, nbrs = rec
                in_set[v] = not any(in_set[u] for u in nbrs)
            elif rec[0] == "nf":
                _, v, nbrs, vp = rec
                if in_set[vp]:
                    for u in nbrs:
                        in_set[u] = True
                    in_set[v] = False
                    in_set[vp] = False
                else:
                    in_set[v] = True
        return np.array(in_set[: self.n_orig], dtype=bool)

    def kernel_stats(self) -> Tuple[int, int]:
        alive = self.alive_vertices()
        nv = len(alive)
        ne = sum(len(self.adj[v]) for v in alive) // 2
        return nv, ne


# --------------------------------------------------------------------- #
# Public entry points
# --------------------------------------------------------------------- #
def reduce_graph(g: Graph, cfg: Optional[SeqConfig] = None) -> SequentialReducer:
    r = SequentialReducer(g, cfg)
    r.reduce()
    return r


def solve_reduce_and_peel(
    g: Graph, cfg: Optional[SeqConfig] = None
) -> Tuple[int, np.ndarray]:
    """HtWIS: reduce to fixpoint, peel one vertex, repeat; reconstruct."""
    r = SequentialReducer(g, cfg)
    r.reduce()
    while r.peel_one() is not None:
        r.reduce()
    members = r.reconstruct()
    assert g.is_independent_set(members), "reconstruction must be independent"
    return g.set_weight(members), members


def solve_greedy(g: Graph) -> Tuple[int, np.ndarray]:
    """Deterministic priority greedy == weighted Luby with (w, -id) priority.

    The distributed GS/GA solver must produce exactly this set (§6: a vertex
    is included iff it maximises weight among its neighbors, PE-rank/id
    tie-breaking) — used as its cross-check oracle.
    """
    order = sorted(range(g.n), key=lambda v: (-int(g.weights[v]), v))
    members = np.zeros(g.n, dtype=bool)
    blocked = np.zeros(g.n, dtype=bool)
    for v in order:
        if not blocked[v]:
            members[v] = True
            blocked[v] = True
            blocked[g.neighbors(v)] = True
    return g.set_weight(members), members


def solve_reduce_and_greedy(
    g: Graph, cfg: Optional[SeqConfig] = None
) -> Tuple[int, np.ndarray]:
    r = SequentialReducer(g, cfg)
    r.reduce()
    # Greedy on the residual kernel, then reconstruct folds.
    alive = r.alive_vertices()
    order = sorted(alive, key=lambda v: (-r.w[v], v))
    blocked = set()
    for v in order:
        if v not in blocked:
            r.status[v] = INCLUDED
            blocked.add(v)
            blocked.update(r.adj[v])
        else:
            r.status[v] = EXCLUDED
    members = r.reconstruct()
    assert g.is_independent_set(members)
    return g.set_weight(members), members
