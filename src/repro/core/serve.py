"""MWIS-as-a-service: batched many-instance solving on the unified engine.

The paper's distributed reductions shrink ONE giant instance across many
PEs; the production inverse is thousands of small/medium instances per
second (conflict scheduling, ad-slot auctions, spectrum allocation).  This
module is that front end, built on three observations:

  * **shape bucketing** — ``partition_graph(..., pad_to=cell)`` already
    pads an instance into a static shape cell, so every instance admitted
    to one cell is the same pytree of array shapes; a batch of them is one
    leading axis.  The bucket table is the ``kind="serve"`` rows of
    :data:`repro.configs.base.MWIS_SHAPES` (smallest cell with
    ``L >= n`` and ``E >= 2m`` wins).
  * **vmap over the union path** — the solver bodies are already traceable
    array-in/array-out (:func:`repro.core.solvers.solve_union_arrays`), so
    the batched solver is literally ``jax.vmap`` of the single-instance
    program.  Every op in the solve is integer/bool, so the batched run is
    **bit-identical** per instance to the unbatched path on every backend
    (vmap reshapes the ops, it never reassociates them); while-loop trip
    counts couple across the batch, but every round body is idempotent at
    its fixpoint, so extra rounds are no-ops.
  * **topology-keyed reuse** — the expensive host-side work (partition,
    window payloads, blocked-ELL ``SegPlan`` packing + autotune) depends
    only on the edge list, not the weights.  A :class:`~repro.core.engine.
    PlanCache` keyed by :func:`~repro.core.engine.topology_hash` makes a
    repeated topology (the common case: the same conflict graph re-solved
    with fresh bids every auction round) skip straight to the device call
    with only a weight-vector refill.

Blocked/pallas batching: all plans in one cell share ``r_blk`` (fixed per
cell) and row count, so they stack after padding to a shared edge budget.
The shared E_BLK is a per-(cell, batch) **high-water mark** — it only
grows, so recompiles are monotone and bounded, and the padded slots are
by construction ignored by the kernels (bit-identity is preserved).

Donation: the per-request weight planes are donated to the jitted batched
solver on accelerator backends (buffer reuse for the hot serving loop);
on CPU jax cannot donate, so the flag is elided to keep logs clean.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as CFG
from repro.core import engine as E
from repro.core import solvers as SOL
from repro.core.graph import Graph
from repro.core.partition import partition_graph


class ServeCell(NamedTuple):
    """One resolved serving bucket (a kind="serve" MWIS_SHAPES row)."""

    name: str
    L: int      # max vertices
    E: int      # max directed edges (2m)
    G: int      # ghost pad (p=1: floor only)
    B: int      # board pad
    S: int      # send-list pad
    D: int      # window cap
    Dc: int     # common-neighborhood cap
    schedule: str
    r_blk: int  # blocked-ELL row-block height (shared across the cell)
    e_blk: int  # blocked-ELL edge-budget floor (high-water mark seed)


def serve_cells() -> Tuple[ServeCell, ...]:
    """The bucket table, ascending by capacity."""
    cells = []
    for name, meta in CFG.MWIS_SHAPES.items():
        if meta.get("kind") != "serve":
            continue
        seg = meta.get("seg_blk", {})
        cells.append(ServeCell(
            name=name, L=meta["L"], E=meta["E"], G=meta["G"], B=meta["B"],
            S=meta["S"], D=meta["D"], Dc=meta["Dc"],
            schedule=meta.get("schedule", "cheap-fused"),
            r_blk=seg.get("r_blk", E.R_BLK),
            e_blk=seg.get("e_blk", E.E_BLK_MULTIPLE),
        ))
    cells.sort(key=lambda c: (c.L, c.E))
    return tuple(cells)


def bucket_for(n: int, directed_edges: int,
               cells: Optional[Sequence[ServeCell]] = None) -> ServeCell:
    """Smallest cell admitting an instance with n vertices / 2m directed
    edges; raises ValueError (naming the limits) when none fits."""
    cells = tuple(cells) if cells is not None else serve_cells()
    for c in cells:
        if n <= c.L and directed_edges <= c.E:
            return c
    big = cells[-1] if cells else None
    raise ValueError(
        f"instance (n={n}, directed_edges={directed_edges}) exceeds every "
        f"serve cell; largest is "
        f"{big.name if big else '<none>'} "
        f"(L={big.L if big else 0}, E={big.E if big else 0}) — route giant "
        f"instances through the distributed path (repro.core.solvers.solve)"
    )


class Topology(NamedTuple):
    """Cached per-topology artifact: everything derived from the edge list.

    ``prob`` is a p=1 UnionProblem whose w0 is a placeholder — requests
    refill only the weight plane.  ``n`` is the true (unpadded) vertex
    count; members/weights are read back as ``members[:n]``.
    """

    prob: SOL.UnionProblem
    n: int


def _pack_topology(g: Graph, cell: ServeCell, backend: str) -> Topology:
    pg = partition_graph(
        g, 1, window_cap=cell.D, common_cap=cell.Dc,
        pad_to=dict(L=cell.L, G=cell.G, E=cell.E, B=cell.B, S=cell.S),
    )
    if pg.L != cell.L or pg.E != cell.E or pg.G != cell.G:
        raise ValueError(
            f"instance broke out of cell {cell.name}: padded "
            f"(L={pg.L}, E={pg.E}, G={pg.G}) vs cell "
            f"(L={cell.L}, E={cell.E}, G={cell.G})"
        )
    prob = SOL.build_union_problem(
        pg, backend, None if backend == "jnp" else cell.r_blk
    )
    return Topology(prob=prob, n=g.n)


def _weight_plane(g: Graph, cell: ServeCell) -> np.ndarray:
    w0 = np.zeros(cell.L + cell.G + 1, dtype=np.int32)
    w0[: g.n] = g.weights
    return w0


class ServeResult(NamedTuple):
    members: np.ndarray   # [n] bool — the independent set
    weight: int           # its weight under the request's weight vector


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving knobs (algo/backend/schedule as in DisReduConfig)."""

    algo: str = "rg"              # greedy | rg | rnp
    backend: str = "jnp"          # jnp | blocked | pallas
    schedule: Optional[str] = None  # None -> per-cell default
    heavy_k: int = 8
    use_heavy: bool = True
    max_rounds: int = 64
    cache_entries: int = 256      # topology-cache bound (LRU)
    max_batch: int = 64           # largest admitted device batch


class MWISService:
    """Bucketing → plan cache → vmapped engine → donation.

    ``solve_batch`` groups requests by serve cell, pads each group to a
    static batch size (:data:`repro.configs.base.MWIS_SERVE_BATCH_SIZES`),
    and dispatches one jitted vmapped solve per (cell, batch) program.
    Results come back in request order.
    """

    def __init__(self, cfg: ServeConfig = ServeConfig(),
                 cells: Optional[Sequence[ServeCell]] = None):
        if cfg.algo not in ("greedy", "rg", "rnp"):
            raise ValueError(f"unknown serve algo {cfg.algo!r}")
        if cfg.backend not in E.BACKENDS:
            raise ValueError(
                f"unknown backend {cfg.backend!r}; available: {E.BACKENDS}"
            )
        self.cfg = cfg
        self.cells = tuple(cells) if cells is not None else serve_cells()
        if not self.cells:
            raise ValueError("no serve cells configured (MWIS_SHAPES has "
                             "no kind='serve' rows)")
        self.cache = E.PlanCache(max_entries=cfg.cache_entries)
        self._batched_fns: Dict[tuple, object] = {}
        self._eblk_hwm: Dict[str, int] = {}
        self.compiles = 0

    # ------------------------------------------------------------------ #
    # request admission
    # ------------------------------------------------------------------ #
    def _topology(self, g: Graph, cell: ServeCell) -> Topology:
        key = (
            cell.name,
            E.topology_hash(g.edge_sources(), g.indices, g.n),
            self.cfg.backend != "jnp",
        )
        return self.cache.get_or_build(
            key, lambda: _pack_topology(g, cell, self.cfg.backend)
        )

    # ------------------------------------------------------------------ #
    # the jitted (cell × batch) programs
    # ------------------------------------------------------------------ #
    def _batched_fn(self, cell: ServeCell, e_blk: int):
        sched = self.cfg.schedule or cell.schedule
        key = (cell.name, self.cfg.backend, self.cfg.algo, sched, e_blk)
        fn = self._batched_fns.get(key)
        if fn is not None:
            return fn
        cfg = self.cfg

        def one(w0, is_local, is_ghost, aux, halo, plan):
            state, members = SOL.solve_union_arrays(
                w0, is_local, is_ghost, aux, halo, plan,
                algo=cfg.algo, heavy_k=cfg.heavy_k,
                use_heavy=cfg.use_heavy, sweeps=1_000_000,
                max_rounds=cfg.max_rounds, p=1, schedule=sched,
                backend=cfg.backend,
            )
            return members, state.offset

        plan_axes = None if cfg.backend == "jnp" else 0
        batched = jax.vmap(one, in_axes=(0, 0, 0, 0, 0, plan_axes))
        # donate the per-request weight plane on accelerators; CPU jax
        # cannot honor donation and would warn on every call
        donate = () if jax.default_backend() == "cpu" else (0,)
        fn = jax.jit(batched, donate_argnums=donate)
        self._batched_fns[key] = fn
        self.compiles += 1
        return fn

    def _batch_size(self, k: int) -> int:
        for b in CFG.MWIS_SERVE_BATCH_SIZES:
            if b >= k and b <= self.cfg.max_batch:
                return b
        return min(max(CFG.MWIS_SERVE_BATCH_SIZES), self.cfg.max_batch)

    # ------------------------------------------------------------------ #
    # solving
    # ------------------------------------------------------------------ #
    def _solve_cell_chunk(
        self, cell: ServeCell, topos: List[Topology]
    ) -> List[np.ndarray]:
        """Solve up to max_batch same-cell topologies; returns [n_i] masks."""
        k = len(topos)
        bt = self._batch_size(k)
        pad = [topos[-1]] * (bt - k)          # repeat last; results dropped
        batch = topos + pad

        def stack(leaves):
            return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

        probs = [t.prob for t in batch]
        w0s = stack([p.w0 for p in probs])
        is_local = stack([p.is_local for p in probs])
        is_ghost = stack([p.is_ghost for p in probs])
        auxs = stack([p.aux for p in probs])
        halos = stack([p.halo for p in probs])
        if self.cfg.backend == "jnp":
            plans = None
            e_blk = 0
        else:
            need = max(p.plan.edge_perm.shape[1] for p in probs)
            hwm = max(self._eblk_hwm.get(cell.name, cell.e_blk), need)
            self._eblk_hwm[cell.name] = hwm
            plans = E.stack_plans([p.plan for p in probs], e_blk=hwm)
            e_blk = hwm
        fn = self._batched_fn(cell, e_blk)
        members, _ = fn(w0s, is_local, is_ghost, auxs, halos, plans)
        members = np.asarray(members)
        return [members[i, : t.n] for i, t in enumerate(topos)]

    def solve_batch(self, graphs: Sequence[Graph]) -> List[ServeResult]:
        """Solve many instances; results in request order."""
        order: Dict[str, List[int]] = {}
        cells_by_name = {c.name: c for c in self.cells}
        topos: List[Optional[Topology]] = [None] * len(graphs)
        for i, g in enumerate(graphs):
            cell = bucket_for(g.n, g.num_directed_edges, self.cells)
            # per-request weight refill on a cached (or fresh) topology
            topo = self._topology(g, cell)
            topos[i] = Topology(
                prob=topo.prob._replace(
                    w0=jnp.asarray(_weight_plane(g, cell))
                ),
                n=topo.n,
            )
            order.setdefault(cell.name, []).append(i)

        out: List[Optional[ServeResult]] = [None] * len(graphs)
        for cell_name, idxs in order.items():
            cell = cells_by_name[cell_name]
            for c0 in range(0, len(idxs), self.cfg.max_batch):
                chunk = idxs[c0 : c0 + self.cfg.max_batch]
                masks = self._solve_cell_chunk(
                    cell, [topos[i] for i in chunk]
                )
                for i, mask in zip(chunk, masks):
                    out[i] = ServeResult(
                        members=mask,
                        weight=int(graphs[i].weights[mask]
                                   .sum(dtype=np.int64)),
                    )
        return out  # type: ignore[return-value]

    def solve_one(self, g: Graph) -> ServeResult:
        return self.solve_batch([g])[0]

    @property
    def stats(self) -> dict:
        s = self.cache.stats
        return dict(
            cache_hits=s.hits, cache_misses=s.misses,
            cache_evictions=s.evictions, cache_size=s.size,
            programs=len(self._batched_fns), compiles=self.compiles,
            e_blk_hwm=dict(self._eblk_hwm),
        )


# --------------------------------------------------------------------- #
# sustained-throughput measurement (benchmarks/serve_bench.py + CLI)
# --------------------------------------------------------------------- #
def measure_throughput(
    service: MWISService,
    batches: Sequence[Sequence[Graph]],
    *,
    warmup: int = 1,
) -> dict:
    """Drive pre-built request batches through a service; returns
    instances/sec + per-batch latency percentiles (ms).

    ``warmup`` counts full passes over the batch list before timing, so
    every (cell × batch-bucket) program is compiled (and every topology
    cached) before the measured pass — the steady serving state.
    """
    for _ in range(warmup):
        for b in batches:
            service.solve_batch(list(b))
    lat = []
    n_inst = 0
    t0 = time.perf_counter()
    for b in batches:
        t1 = time.perf_counter()
        service.solve_batch(list(b))
        lat.append((time.perf_counter() - t1) * 1e3)
        n_inst += len(b)
    wall = time.perf_counter() - t0
    lat_a = np.asarray(lat)
    return dict(
        instances=n_inst,
        instances_per_sec=round(n_inst / wall, 1),
        p50_ms=round(float(np.percentile(lat_a, 50)), 3),
        p99_ms=round(float(np.percentile(lat_a, 99)), 3),
        batches=len(batches),
    )
