"""MWIS-as-a-service: batched many-instance solving on the unified engine.

The paper's distributed reductions shrink ONE giant instance across many
PEs; the production inverse is thousands of small/medium instances per
second (conflict scheduling, ad-slot auctions, spectrum allocation).  This
module is that front end, built on three observations:

  * **shape bucketing** — ``partition_graph(..., pad_to=cell)`` already
    pads an instance into a static shape cell, so every instance admitted
    to one cell is the same pytree of array shapes; a batch of them is one
    leading axis.  The bucket table is the ``kind="serve"`` rows of
    :data:`repro.configs.base.MWIS_SHAPES` (smallest cell with
    ``L >= n`` and ``E >= 2m`` wins).
  * **vmap over the union path** — the solver bodies are already traceable
    array-in/array-out (:func:`repro.core.solvers.solve_union_arrays`), so
    the batched solver is literally ``jax.vmap`` of the single-instance
    program.  Every op in the solve is integer/bool, so the batched run is
    **bit-identical** per instance to the unbatched path on every backend
    (vmap reshapes the ops, it never reassociates them); while-loop trip
    counts couple across the batch, but every round body is idempotent at
    its fixpoint, so extra rounds are no-ops.
  * **topology-keyed reuse** — the expensive host-side work (partition,
    window payloads, blocked-ELL ``SegPlan`` packing + autotune) depends
    only on the edge list, not the weights.  A :class:`~repro.core.engine.
    PlanCache` keyed by :func:`~repro.core.engine.topology_hash` makes a
    repeated topology (the common case: the same conflict graph re-solved
    with fresh bids every auction round) skip straight to the device call
    with only a weight-vector refill.

Blocked/pallas batching: all plans in one cell share ``r_blk`` (fixed per
cell) and row count, so they stack after padding to a shared edge budget.
The shared E_BLK is a per-(cell, batch) **high-water mark** — it only
grows, so recompiles are monotone and bounded, and the padded slots are
by construction ignored by the kernels (bit-identity is preserved).

Multi-device serving (the throughput lever past one accelerator):

  * **batch-axis sharding** — every instance in a stacked chunk is
    independent, so the batch axis shards trivially over a flat ``serve``
    mesh (:func:`repro.launch.mesh.make_serve_mesh`): the stacked arrays
    are ``jax.device_put`` with a ``NamedSharding`` on their leading axis
    and the jitted vmapped program runs SPMD (the only cross-device
    traffic is the while-loop condition's OR-reduce, which only couples
    trip counts — every round body is idempotent at its fixpoint, so the
    per-instance results stay **bit-identical** to the single-device
    path).  Batch sizes are rounded up to a multiple of the active device
    count (phantom repeat-last instances, discarded on fetch) so shards
    always split evenly and a ragged tail never compiles a one-off shape.
  * **overlapped host pipeline** — within one ``solve_batch`` call the
    chunks are double-buffered: while the device solves chunk *k*, the
    host packs, stacks and transfers chunk *k+1* (jax dispatch is async,
    so the weight refill + ``jnp.stack`` + H2D of the next chunk hide
    under the in-flight solve instead of serializing with it — the same
    communication/computation overlap DisReduA uses between PEs, applied
    to the host→device edge).  Per-stage wall time (pack / transfer /
    solve / fetch) and the achieved overlap ratio are recorded in
    ``MWISService.stats``.

Donation: the per-request weight planes are donated to the jitted batched
solver on accelerator backends (buffer reuse for the hot serving loop);
on CPU jax cannot donate, so the flag is elided to keep logs clean.

Robustness (the hardened-serving layer):

  * **admission** — requests pass :func:`repro.core.validate.canonicalize`
    (``ServeConfig.validate``): harmless defects (self-loops, duplicate or
    asymmetric directed edges, unsorted rows) are repaired, rejects
    (NaN/negative/overflow weights, broken CSR, out-of-range indices)
    become structured per-request errors with stable reason codes.
  * **per-request fault isolation** — `solve_batch` NEVER raises for a bad
    instance; every :class:`ServeResult` carries ``ok``/``reason``/
    ``error``, so one poisoned request (oversize, malformed, unpackable)
    degrades to an error entry while every healthy instance in the batch
    still solves bit-identically to the pre-hardening path.  Oversize
    instances are rejected with ``reason="oversize"`` — route those
    through the distributed path (:func:`repro.core.solvers.solve`).
  * **backend fallback** — a compile/runtime failure of the configured
    backend falls down the chain ``pallas → blocked → jnp`` (all three are
    bit-identical by the engine contract, so degradation is performance
    only); failed plan builds stay out of the `PlanCache`
    (`get_or_build` never caches a raising build), and fallbacks are
    counted in ``MWISService.stats``.
  * **verified outputs** — ``ServeConfig.verify`` ∈ ``off | sample |
    full`` audits results post-solve (:func:`repro.core.validate.
    verify_result`): independence + weight recomputation.  ``sample``
    checks the first request of every device chunk; ``full`` checks all.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as CFG
from repro.core import engine as E
from repro.core import solvers as SOL
from repro.core import validate as V
from repro.core.graph import Graph
from repro.core.partition import partition_graph

#: Backend degradation order: a failing backend falls to the next entry.
FALLBACK_CHAIN = {
    "pallas": ("pallas", "blocked", "jnp"),
    "blocked": ("blocked", "jnp"),
    "jnp": ("jnp",),
}


class ServeCell(NamedTuple):
    """One resolved serving bucket (a kind="serve" MWIS_SHAPES row)."""

    name: str
    L: int      # max vertices
    E: int      # max directed edges (2m)
    G: int      # ghost pad (p=1: floor only)
    B: int      # board pad
    S: int      # send-list pad
    D: int      # window cap
    Dc: int     # common-neighborhood cap
    schedule: str
    r_blk: int  # blocked-ELL row-block height (shared across the cell)
    e_blk: int  # blocked-ELL edge-budget floor (high-water mark seed)
    serve_devices: Optional[int] = None  # batch-axis device cap (None=mesh)
    pipeline: bool = True                # overlapped pack/transfer opt-out


def _cells_of_kind(kind: str) -> Tuple[ServeCell, ...]:
    cells = []
    for name, meta in CFG.MWIS_SHAPES.items():
        if meta.get("kind") != kind:
            continue
        seg = meta.get("seg_blk", {})
        cells.append(ServeCell(
            name=name, L=meta["L"], E=meta["E"], G=meta["G"], B=meta["B"],
            S=meta["S"], D=meta["D"], Dc=meta["Dc"],
            schedule=meta.get("schedule", "cheap-fused"),
            r_blk=seg.get("r_blk", E.R_BLK),
            e_blk=seg.get("e_blk", E.E_BLK_MULTIPLE),
            serve_devices=meta.get("serve_devices"),
            pipeline=meta.get("pipeline", True),
        ))
    cells.sort(key=lambda c: (c.L, c.E))
    return tuple(cells)


def serve_cells() -> Tuple[ServeCell, ...]:
    """The bucket table, ascending by capacity."""
    return _cells_of_kind("serve")


def descent_entry_cells() -> Tuple[ServeCell, ...]:
    """kind="descent" MWIS_SHAPES rows — oversize *entry* shapes for the
    staged path (never batched; a solve entering here descends into the
    serve cells as soon as reduction shrinks the kernel)."""
    return _cells_of_kind("descent")


def bucket_for(n: int, directed_edges: int,
               cells: Optional[Sequence[ServeCell]] = None) -> ServeCell:
    """Smallest cell admitting an instance with n vertices / 2m directed
    edges; raises ValueError (naming the limits) when none fits."""
    cells = tuple(cells) if cells is not None else serve_cells()
    for c in cells:
        if n <= c.L and directed_edges <= c.E:
            return c
    big = cells[-1] if cells else None
    raise ValueError(
        f"instance (n={n}, directed_edges={directed_edges}) exceeds every "
        f"serve cell; largest is "
        f"{big.name if big else '<none>'} "
        f"(L={big.L if big else 0}, E={big.E if big else 0}) — route giant "
        f"instances through the distributed path (repro.core.solvers.solve)"
    )


class Topology(NamedTuple):
    """Cached per-topology artifact: everything derived from the edge list.

    ``prob`` is a p=1 UnionProblem whose w0 is a placeholder — requests
    refill only the weight plane.  ``n`` is the true (unpadded) vertex
    count; members/weights are read back as ``members[:n]``.
    """

    prob: SOL.UnionProblem
    n: int


def _pack_topology(g: Graph, cell: ServeCell, backend: str) -> Topology:
    pg = partition_graph(
        g, 1, window_cap=cell.D, common_cap=cell.Dc,
        pad_to=dict(L=cell.L, G=cell.G, E=cell.E, B=cell.B, S=cell.S),
    )
    if pg.L != cell.L or pg.E != cell.E or pg.G != cell.G:
        raise ValueError(
            f"instance broke out of cell {cell.name}: padded "
            f"(L={pg.L}, E={pg.E}, G={pg.G}) vs cell "
            f"(L={cell.L}, E={cell.E}, G={cell.G})"
        )
    prob = SOL.build_union_problem(
        pg, backend, None if backend == "jnp" else cell.r_blk
    )
    return Topology(prob=prob, n=g.n)


def _weight_plane(g: Graph, cell: ServeCell) -> np.ndarray:
    w0 = np.zeros(cell.L + cell.G + 1, dtype=np.int32)
    w0[: g.n] = g.weights
    return w0


class ServeResult(NamedTuple):
    """One request's outcome.  ``ok=False`` results carry a stable
    ``reason`` code (:mod:`repro.core.validate` REASON_*) and a
    human-readable ``error``; their mask is all-False and weight 0.
    ``reason="oversize"`` means the instance exceeds every serve cell —
    route it through the distributed path, ``repro.core.solvers.solve``.
    """

    members: np.ndarray   # [n] bool — the independent set
    weight: int           # its weight under the request's weight vector
    ok: bool = True
    reason: Optional[str] = None   # machine-readable error code
    error: Optional[str] = None    # human-readable detail


def _error_result(n: int, reason: str, detail: str) -> ServeResult:
    return ServeResult(
        members=np.zeros(max(n, 0), dtype=bool), weight=0,
        ok=False, reason=reason, error=f"{reason}: {detail}",
    )


class _Staged(NamedTuple):
    """A chunk stacked to its static batch shape and placed on the serve
    mesh (device_put already issued), ready to launch."""

    cell: ServeCell
    backend: str
    topos: Tuple[Topology, ...]   # the real (unpadded) chunk members
    args: tuple                   # (w0s, is_local, is_ghost, auxs, halos,
                                  #  plans) — leading axis = static batch
    e_blk: int
    rec: dict                     # per-chunk stage-timing record


class _Inflight(NamedTuple):
    """A launched chunk whose result is an unretired jax future."""

    staged: _Staged
    members: jax.Array            # async [bt, L+G+1] bool
    t_dispatch: float


class _Pending(NamedTuple):
    """A dispatched pipeline chunk awaiting retirement.  ``inflight`` is
    None when dispatch itself failed — the retire step then re-runs the
    chunk through the synchronous fallback-chain path."""

    inflight: Optional[_Inflight]
    cell: ServeCell
    good: List[int]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving knobs (algo/backend/schedule as in DisReduConfig)."""

    algo: str = "rg"              # greedy | rg | rnp
    backend: str = "jnp"          # jnp | blocked | pallas
    schedule: Optional[str] = None  # None -> per-cell default
    heavy_k: int = 8
    use_heavy: bool = True
    max_rounds: int = 64
    cache_entries: int = 256      # topology-cache bound (LRU)
    max_batch: int = 64           # largest admitted device batch
    validate: bool = True         # canonicalize/reject requests on admission
    verify: str = "off"           # post-solve audit: off | sample | full
    fallback: bool = True         # walk FALLBACK_CHAIN on backend failure
    # --- multi-device batch sharding + overlapped host pipeline ------- #
    devices: Optional[int] = None  # serve-mesh size (None = every visible
                                   # device; > visible raises at init)
    pipeline: bool = True          # overlap pack/H2D of chunk k+1 with the
                                   # in-flight solve of chunk k
    # --- shape descent (solvers.solve_staged) ------------------------- #
    descent: str = "off"          # off | auto — big cells take the staged
                                  # path and shrink mid-solve
    descent_min_L: int = 1024     # smallest cell L routed through descent
                                  # (default: serve_m and up)
    descent_every: int = 2        # stage length between descent checks


class MWISService:
    """Bucketing → plan cache → vmapped engine → donation.

    ``solve_batch`` groups requests by serve cell, pads each group to a
    static batch size (:data:`repro.configs.base.MWIS_SERVE_BATCH_SIZES`),
    and dispatches one jitted vmapped solve per (cell, batch) program.
    Results come back in request order.
    """

    def __init__(self, cfg: ServeConfig = ServeConfig(),
                 cells: Optional[Sequence[ServeCell]] = None):
        if cfg.algo not in ("greedy", "rg", "rnp"):
            raise ValueError(f"unknown serve algo {cfg.algo!r}")
        if cfg.backend not in E.BACKENDS:
            raise ValueError(
                f"unknown backend {cfg.backend!r}; available: {E.BACKENDS}"
            )
        if cfg.verify not in ("off", "sample", "full"):
            raise ValueError(
                f"unknown verify mode {cfg.verify!r}; "
                "available: ('off', 'sample', 'full')"
            )
        if cfg.descent not in ("off", "auto"):
            raise ValueError(
                f"unknown descent mode {cfg.descent!r}; "
                "available: ('off', 'auto')"
            )
        visible = jax.device_count()
        if cfg.devices is not None and not 1 <= cfg.devices <= visible:
            raise ValueError(
                f"serve devices={cfg.devices} exceeds the {visible} "
                f"visible jax device(s) — launch with more devices or set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{cfg.devices} for CPU testing"
            )
        self.cfg = cfg
        self.cells = tuple(cells) if cells is not None else serve_cells()
        self.descent_cells = descent_entry_cells() \
            if cfg.descent == "auto" else ()
        if not self.cells:
            raise ValueError("no serve cells configured (MWIS_SHAPES has "
                             "no kind='serve' rows)")
        self.cache = E.PlanCache(max_entries=cfg.cache_entries)
        self._batched_fns: Dict[tuple, object] = {}
        self._eblk_hwm: Dict[str, int] = {}
        self.compiles = 0
        # active backend: starts at cfg.backend, demoted down
        # FALLBACK_CHAIN when a program build/execute fails
        self._backend = cfg.backend
        self._ndev = cfg.devices if cfg.devices is not None else visible
        self._meshes: Dict[int, object] = {}   # device count -> serve Mesh
        self._stage_totals = dict(pack=0.0, transfer=0.0, solve=0.0,
                                  fetch=0.0)       # cumulative ms per stage
        self._stage_log: deque = deque(maxlen=2048)  # per-chunk timing recs
        self._wall_s = 0.0                 # chunk-processing wall seconds
        self.counters = dict(
            requests=0, rejected=0, repaired=0, pack_errors=0,
            solve_errors=0, fallbacks=0, verify_checked=0,
            verify_failures=0, descent_solves=0, descents=0,
            oversize_admitted=0, chunks=0, pipelined_chunks=0,
            pipeline_retries=0,
        )
        self.events: List[tuple] = []   # (kind, detail) robustness log

    # ------------------------------------------------------------------ #
    # request admission
    # ------------------------------------------------------------------ #
    def _topology(self, g: Graph, cell: ServeCell, backend: str) -> Topology:
        key = (
            cell.name,
            E.topology_hash(g.edge_sources(), g.indices, g.n),
            backend != "jnp",
        )
        return self.cache.get_or_build(
            key, lambda: _pack_topology(g, cell, backend)
        )

    # ------------------------------------------------------------------ #
    # the jitted (cell × batch) programs
    # ------------------------------------------------------------------ #
    def _batched_fn(self, cell: ServeCell, e_blk: int, backend: str):
        sched = self.cfg.schedule or cell.schedule
        key = (cell.name, backend, self.cfg.algo, sched, e_blk)
        fn = self._batched_fns.get(key)
        if fn is not None:
            return fn
        cfg = self.cfg

        def one(w0, is_local, is_ghost, aux, halo, plan):
            state, members = SOL.solve_union_arrays(
                w0, is_local, is_ghost, aux, halo, plan,
                algo=cfg.algo, heavy_k=cfg.heavy_k,
                use_heavy=cfg.use_heavy, sweeps=1_000_000,
                max_rounds=cfg.max_rounds, p=1, schedule=sched,
                backend=backend,
            )
            return members, state.offset

        plan_axes = None if backend == "jnp" else 0
        batched = jax.vmap(one, in_axes=(0, 0, 0, 0, 0, plan_axes))
        # donate the per-request weight plane on accelerators; CPU jax
        # cannot honor donation and would warn on every call
        donate = () if jax.default_backend() == "cpu" else (0,)
        fn = jax.jit(batched, donate_argnums=donate)
        self._batched_fns[key] = fn
        self.compiles += 1
        return fn

    def _cell_ndev(self, cell: Optional[ServeCell]) -> int:
        """Active device count for a cell's batch axis (cell cap ∧ mesh)."""
        nd = max(1, self._ndev)
        if cell is not None and cell.serve_devices:
            nd = min(nd, cell.serve_devices)
        return nd

    def _sharding(self, nd: int):
        """NamedSharding splitting a leading batch axis over nd devices."""
        from jax.sharding import NamedSharding, PartitionSpec

        mesh = self._meshes.get(nd)
        if mesh is None:
            from repro.launch.mesh import make_serve_mesh

            mesh = make_serve_mesh(nd)
            self._meshes[nd] = mesh
        return NamedSharding(mesh, PartitionSpec("serve"))

    def _batch_size(self, k: int, cell: Optional[ServeCell] = None) -> int:
        """Static batch size for a k-request chunk: the smallest admitted
        bucket, rounded up to a multiple of the active device count so the
        sharded batch axis always splits evenly (a ragged last shard would
        otherwise pay a full recompile for its one-off padded shape)."""
        nd = self._cell_ndev(cell)

        def up(b: int) -> int:
            return ((b + nd - 1) // nd) * nd

        for b in CFG.MWIS_SERVE_BATCH_SIZES:
            if b >= k and b <= self.cfg.max_batch:
                return up(b)
        return up(max(k, min(max(CFG.MWIS_SERVE_BATCH_SIZES),
                             self.cfg.max_batch)))

    # ------------------------------------------------------------------ #
    # solving: pack -> stage (stack + shard/H2D) -> launch -> fetch
    # ------------------------------------------------------------------ #
    def _new_rec(self, cell: ServeCell, backend: str,
                 pipelined: bool) -> dict:
        return dict(cell=cell.name, backend=backend, batch=0, devices=1,
                    pipelined=pipelined, pack_ms=0.0, transfer_ms=0.0,
                    solve_ms=0.0, fetch_ms=0.0)

    def _log_stages(self, rec: dict) -> None:
        self.counters["chunks"] += 1
        if rec["pipelined"]:
            self.counters["pipelined_chunks"] += 1
        for k in ("pack", "transfer", "solve", "fetch"):
            self._stage_totals[k] += rec[k + "_ms"]
        self._stage_log.append(dict(rec))

    def _pack_requests(
        self,
        cell: ServeCell,
        idxs: List[int],
        graphs: List[Graph],
        out: List[Optional[ServeResult]],
        backend: str,
    ) -> Tuple[List[Topology], List[int]]:
        """Per-request host packing with fault isolation; failed requests
        get error results in ``out`` and drop out of the chunk."""
        topos: List[Topology] = []
        good: List[int] = []
        for i in idxs:
            g = graphs[i]
            try:
                # per-request weight refill on a cached/fresh topology;
                # a raising pack stays OUT of the cache (get_or_build)
                topo = self._topology(g, cell, backend)
                topos.append(Topology(
                    prob=topo.prob._replace(
                        w0=jnp.asarray(_weight_plane(g, cell))
                    ),
                    n=topo.n,
                ))
                good.append(i)
            except Exception as e:  # noqa: BLE001 — isolate the request
                self.counters["pack_errors"] += 1
                self.events.append(("pack_error", cell.name, str(e)))
                out[i] = _error_result(g.n, V.REASON_PACK_FAILED, str(e))
        return topos, good

    def _stage_chunk(
        self, cell: ServeCell, topos: List[Topology], backend: str,
        rec: dict,
    ) -> "_Staged":
        """Stack a chunk to its static batch size and place it: the batch
        axis is padded to a device-count multiple with phantom repeat-last
        instances (results sliced off on fetch) and device_put with a
        ``serve``-mesh NamedSharding when more than one device is active."""
        t0 = time.perf_counter()
        k = len(topos)
        bt = self._batch_size(k, cell)
        batch = list(topos) + [topos[-1]] * (bt - k)

        def stack(leaves):
            return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

        probs = [t.prob for t in batch]
        w0s = stack([p.w0 for p in probs])
        is_local = stack([p.is_local for p in probs])
        is_ghost = stack([p.is_ghost for p in probs])
        auxs = stack([p.aux for p in probs])
        halos = stack([p.halo for p in probs])
        if backend == "jnp":
            plans = None
            e_blk = 0
        else:
            need = max(p.plan.edge_perm.shape[1] for p in probs)
            hwm = max(self._eblk_hwm.get(cell.name, cell.e_blk), need)
            self._eblk_hwm[cell.name] = hwm
            plans = E.stack_plans([p.plan for p in probs], e_blk=hwm)
            e_blk = hwm
        args = (w0s, is_local, is_ghost, auxs, halos, plans)
        t1 = time.perf_counter()
        nd = self._cell_ndev(cell)
        if nd > 1:
            args = jax.device_put(args, self._sharding(nd))
        t2 = time.perf_counter()
        rec["pack_ms"] += (t1 - t0) * 1e3
        rec["transfer_ms"] += (t2 - t1) * 1e3
        rec["batch"] = bt
        rec["devices"] = nd
        return _Staged(cell=cell, backend=backend, topos=tuple(topos),
                       args=args, e_blk=e_blk, rec=rec)

    def _launch_chunk(self, staged: "_Staged") -> "_Inflight":
        """Dispatch the jitted vmapped solve; returns without blocking
        (jax dispatch is async — the host is free to pack the next chunk
        while this one runs on the device shards)."""
        fn = self._batched_fn(staged.cell, staged.e_blk, staged.backend)
        t0 = time.perf_counter()
        members, _ = fn(*staged.args)
        return _Inflight(staged=staged, members=members, t_dispatch=t0)

    def _fetch_chunk(self, inflight: "_Inflight") -> List[np.ndarray]:
        """Block on the in-flight solve and read back the [n_i] masks."""
        rec = inflight.staged.rec
        members = inflight.members.block_until_ready()
        t1 = time.perf_counter()
        rec["solve_ms"] += (t1 - inflight.t_dispatch) * 1e3
        members = np.asarray(members)
        rec["fetch_ms"] += (time.perf_counter() - t1) * 1e3
        self._log_stages(rec)
        return [members[i, : t.n]
                for i, t in enumerate(inflight.staged.topos)]

    def _execute_chunk(
        self, cell: ServeCell, topos: List[Topology], backend: str
    ) -> List[np.ndarray]:
        """Solve up to max_batch same-cell topologies; returns [n_i] masks.

        Raises on program build/execute failure — `_solve_chunk` wraps it
        with the fallback chain.  (Tests monkeypatch this seam to inject
        backend failures.)
        """
        rec = self._new_rec(cell, backend, pipelined=False)
        staged = self._stage_chunk(cell, topos, backend, rec)
        return self._fetch_chunk(self._launch_chunk(staged))

    def _solve_chunk(
        self,
        cell: ServeCell,
        idxs: List[int],
        graphs: List[Graph],
        out: List[Optional[ServeResult]],
    ) -> None:
        """Pack + solve one (cell, ≤max_batch) chunk with per-request
        isolation and the backend fallback chain; fills ``out``."""
        while True:
            backend = self._backend
            topos, good = self._pack_requests(cell, idxs, graphs, out,
                                              backend)
            if not good:
                return
            try:
                masks = self._execute_chunk(cell, topos, backend)
            except Exception as e:  # noqa: BLE001 — degrade, don't abort
                chain = FALLBACK_CHAIN[self.cfg.backend]
                pos = chain.index(backend) if backend in chain else len(chain)
                nxt = chain[pos + 1] if pos + 1 < len(chain) else None
                if nxt is None or not self.cfg.fallback:
                    self.counters["solve_errors"] += 1
                    self.events.append(
                        ("backend_failed", cell.name, backend, str(e)))
                    for i in good:
                        out[i] = _error_result(
                            graphs[i].n, V.REASON_BACKEND_FAILED,
                            f"backend {backend!r} failed with no fallback "
                            f"left: {e}")
                    return
                self.counters["fallbacks"] += 1
                self.events.append(("fallback", backend, nxt, str(e)))
                self._backend = nxt
                continue        # retry the chunk on the demoted backend
            for k, i in enumerate(good):
                out[i] = self._finish_result(
                    graphs[i], masks[k], check=(self.cfg.verify == "full")
                    or (self.cfg.verify == "sample" and k == 0))
            return

    # ------------------------------------------------------------------ #
    # the double-buffered chunk pipeline
    # ------------------------------------------------------------------ #
    def _dispatch_chunk(
        self,
        cell: ServeCell,
        idxs: List[int],
        graphs: List[Graph],
        out: List[Optional[ServeResult]],
    ) -> Optional["_Pending"]:
        """Pack + stage + launch one chunk without blocking.  Returns None
        when nothing in the chunk is solvable; a dispatch failure comes
        back as a `_Pending` with ``inflight=None`` — retired by re-running
        the chunk through the synchronous fallback-chain path."""
        backend = self._backend
        rec = self._new_rec(cell, backend, pipelined=True)
        t0 = time.perf_counter()
        topos, good = self._pack_requests(cell, idxs, graphs, out, backend)
        rec["pack_ms"] += (time.perf_counter() - t0) * 1e3
        if not good:
            return None
        try:
            staged = self._stage_chunk(cell, topos, backend, rec)
            inflight = self._launch_chunk(staged)
        except Exception as e:  # noqa: BLE001 — degrade via the sync path
            self.counters["pipeline_retries"] += 1
            self.events.append(
                ("pipeline_retry", cell.name, backend, str(e)))
            return _Pending(inflight=None, cell=cell, good=good)
        return _Pending(inflight=inflight, cell=cell, good=good)

    def _retire_chunk(
        self,
        pending: "_Pending",
        graphs: List[Graph],
        out: List[Optional[ServeResult]],
    ) -> None:
        """Fetch a dispatched chunk and finish its results; any failure
        (dispatch or in-flight) re-runs the chunk synchronously through
        `_solve_chunk`, which owns the backend fallback chain."""
        if pending.inflight is None:
            self._solve_chunk(pending.cell, pending.good, graphs, out)
            return
        try:
            masks = self._fetch_chunk(pending.inflight)
        except Exception as e:  # noqa: BLE001 — degrade via the sync path
            self.counters["pipeline_retries"] += 1
            self.events.append(
                ("pipeline_retry", pending.cell.name,
                 pending.inflight.staged.backend, str(e)))
            self._solve_chunk(pending.cell, pending.good, graphs, out)
            return
        for k, i in enumerate(pending.good):
            out[i] = self._finish_result(
                graphs[i], masks[k], check=(self.cfg.verify == "full")
                or (self.cfg.verify == "sample" and k == 0))

    def _run_chunks(
        self,
        chunks: List[Tuple[ServeCell, List[int]]],
        graphs: List[Graph],
        out: List[Optional[ServeResult]],
    ) -> None:
        """Run the batch's (cell, idxs) chunks, double-buffered: chunk
        k+1 is packed/staged/launched while chunk k's solve is in flight,
        so host work hides under device time.  Cells opted out of
        pipelining (and single-chunk batches) take the synchronous path —
        results are identical either way, only the overlap differs."""
        t_wall = time.perf_counter()
        pipe = self.cfg.pipeline and len(chunks) > 1
        pending: Optional[_Pending] = None
        for cell, idxs in chunks:
            if not (pipe and cell.pipeline):
                if pending is not None:
                    self._retire_chunk(pending, graphs, out)
                    pending = None
                self._solve_chunk(cell, idxs, graphs, out)
                continue
            nxt = self._dispatch_chunk(cell, idxs, graphs, out)
            if pending is not None:
                self._retire_chunk(pending, graphs, out)
            pending = nxt
        if pending is not None:
            self._retire_chunk(pending, graphs, out)
        self._wall_s += time.perf_counter() - t_wall

    def _solve_staged_one(self, g: Graph, cell: ServeCell) -> ServeResult:
        """One instance through the shape-descent path
        (:func:`repro.core.solvers.solve_staged`): enter at ``cell``'s
        shape, shrink onto smaller serve cells as reduction collapses the
        kernel.  Descent plans go through the shared :class:`PlanCache`
        (counted in ``cache_descent_*``).  Same isolation contract as the
        batched path: never raises, walks the backend fallback chain."""
        cfg = self.cfg
        sched = cfg.schedule or cell.schedule
        while True:
            backend = self._backend
            dcfg = SOL.DisReduConfig(
                heavy_k=cfg.heavy_k, use_heavy=cfg.use_heavy, mode="sync",
                max_rounds=cfg.max_rounds, schedule=sched, backend=backend,
                r_blk=None if backend == "jnp" else cell.r_blk,
                descent=True, descent_every=cfg.descent_every,
            )
            try:
                members, st = SOL.solve_staged(
                    g, 1, cfg.algo, dcfg, plan_cache=self.cache,
                    pad_to=dict(L=cell.L, G=cell.G, E=cell.E, B=cell.B,
                                S=cell.S),
                    window_cap=cell.D, common_cap=cell.Dc,
                )
            except Exception as e:  # noqa: BLE001 — degrade, don't abort
                chain = FALLBACK_CHAIN[self.cfg.backend]
                pos = chain.index(backend) if backend in chain else len(chain)
                nxt = chain[pos + 1] if pos + 1 < len(chain) else None
                if nxt is None or not self.cfg.fallback:
                    self.counters["solve_errors"] += 1
                    self.events.append(
                        ("backend_failed", cell.name, backend, str(e)))
                    return _error_result(
                        g.n, V.REASON_BACKEND_FAILED,
                        f"backend {backend!r} failed with no fallback "
                        f"left: {e}")
                self.counters["fallbacks"] += 1
                self.events.append(("fallback", backend, nxt, str(e)))
                self._backend = nxt
                continue
            self.counters["descent_solves"] += 1
            self.counters["descents"] += int(st["descents"])
            return self._finish_result(
                g, members, check=self.cfg.verify in ("sample", "full"))

    def _finish_result(
        self, g: Graph, mask: np.ndarray, check: bool
    ) -> ServeResult:
        weight = int(g.weights[mask].sum(dtype=np.int64))
        if check:
            self.counters["verify_checked"] += 1
            rep = V.verify_result(g, mask, weight)
            if not rep.ok:
                self.counters["verify_failures"] += 1
                self.events.append(("verify_failure", rep.detail))
                return ServeResult(
                    members=mask, weight=weight, ok=False,
                    reason=rep.reason, error=f"{rep.reason}: {rep.detail}",
                )
        return ServeResult(members=mask, weight=weight)

    def solve_batch(self, graphs: Sequence[Graph]) -> List[ServeResult]:
        """Solve many instances; results in request order.

        Never raises for a bad request: malformed/oversize/unpackable
        instances come back as ``ok=False`` results with stable reason
        codes while the rest of the batch solves normally.
        """
        order: Dict[str, List[int]] = {}
        staged: List[Tuple[int, ServeCell]] = []
        cells_by_name = {c.name: c for c in self.cells}
        admitted: List[Graph] = list(graphs)
        out: List[Optional[ServeResult]] = [None] * len(graphs)
        for i, g in enumerate(graphs):
            self.counters["requests"] += 1
            if self.cfg.validate:
                fixed, rep = V.canonicalize(g)
                if not rep.ok:
                    self.counters["rejected"] += 1
                    self.events.append(("rejected", rep.reason, rep.detail))
                    try:
                        n_bad = int(g.n)
                    except Exception:  # noqa: BLE001 — malformed input
                        n_bad = 0
                    out[i] = _error_result(n_bad, rep.reason, rep.detail)
                    continue
                if rep.repairs:
                    self.counters["repaired"] += 1
                    self.events.append(("repaired", rep.repairs))
                admitted[i] = g = fixed
            if g.n == 0:    # trivially solved; skip the device entirely
                out[i] = ServeResult(members=np.zeros(0, bool), weight=0)
                continue
            try:
                cell = bucket_for(g.n, g.num_directed_edges, self.cells)
            except ValueError as e:
                # oversize for every serve cell — with descent on, admit
                # through a kind="descent" entry shape (staged path only)
                dcell = None
                if self.descent_cells:
                    try:
                        dcell = bucket_for(g.n, g.num_directed_edges,
                                           self.descent_cells)
                    except ValueError:
                        dcell = None
                if dcell is None:
                    self.counters["rejected"] += 1
                    self.events.append(
                        ("rejected", V.REASON_OVERSIZE, str(e)))
                    out[i] = _error_result(g.n, V.REASON_OVERSIZE, str(e))
                    continue
                self.counters["oversize_admitted"] += 1
                staged.append((i, dcell))
                continue
            if (self.cfg.descent == "auto"
                    and cell.L >= self.cfg.descent_min_L):
                staged.append((i, cell))
            else:
                order.setdefault(cell.name, []).append(i)

        chunks: List[Tuple[ServeCell, List[int]]] = []
        for cell_name, idxs in order.items():
            cell = cells_by_name[cell_name]
            for c0 in range(0, len(idxs), self.cfg.max_batch):
                chunks.append((cell, idxs[c0 : c0 + self.cfg.max_batch]))
        self._run_chunks(chunks, admitted, out)
        for i, cell in staged:
            out[i] = self._solve_staged_one(admitted[i], cell)
        return out  # type: ignore[return-value]

    def solve_one(self, g: Graph) -> ServeResult:
        return self.solve_batch([g])[0]

    @property
    def stats(self) -> dict:
        s = self.cache.stats
        stage_ms = {k: round(v, 3) for k, v in self._stage_totals.items()}
        p50 = {}
        for k in ("pack", "transfer", "solve", "fetch"):
            vals = [r[k + "_ms"] for r in self._stage_log]
            p50[k] = round(float(np.median(vals)), 3) if vals else 0.0
        busy_ms = sum(self._stage_totals.values())
        wall_ms = self._wall_s * 1e3
        # fraction of summed stage time hidden under other chunks' device
        # time — 0.0 when serial (wall >= busy), higher when pipelined
        overlap = (max(0.0, 1.0 - wall_ms / busy_ms) if busy_ms > 0
                   else 0.0)
        return dict(
            cache_hits=s.hits, cache_misses=s.misses,
            cache_evictions=s.evictions, cache_size=s.size,
            cache_errors=s.errors,
            cache_descent_hits=s.descent_hits,
            cache_descent_misses=s.descent_misses,
            programs=len(self._batched_fns), compiles=self.compiles,
            e_blk_hwm=dict(self._eblk_hwm),
            backend=self.cfg.backend, backend_active=self._backend,
            devices=max(1, self._ndev),
            pipeline=self.cfg.pipeline,
            stage_ms=stage_ms,
            stage_p50_ms=p50,
            wall_ms=round(wall_ms, 3),
            overlap_ratio=round(overlap, 4),
            **self.counters,
        )


# --------------------------------------------------------------------- #
# sustained-throughput measurement (benchmarks/serve_bench.py + CLI)
# --------------------------------------------------------------------- #
def measure_throughput(
    service: MWISService,
    batches: Sequence[Sequence[Graph]],
    *,
    warmup: int = 1,
) -> dict:
    """Drive pre-built request batches through a service; returns
    instances/sec + per-batch latency percentiles (ms).

    ``warmup`` counts full passes over the batch list before timing, so
    every (cell × batch-bucket) program is compiled (and every topology
    cached) before the measured pass — the steady serving state.
    """
    for _ in range(warmup):
        for b in batches:
            service.solve_batch(list(b))
    lat = []
    n_inst = 0
    t0 = time.perf_counter()
    for b in batches:
        t1 = time.perf_counter()
        service.solve_batch(list(b))
        lat.append((time.perf_counter() - t1) * 1e3)
        n_inst += len(b)
    wall = time.perf_counter() - t0
    lat_a = np.asarray(lat)
    return dict(
        instances=n_inst,
        instances_per_sec=round(n_inst / wall, 1),
        p50_ms=round(float(np.percentile(lat_a, 50)), 3),
        p99_ms=round(float(np.percentile(lat_a, 99)), 3),
        batches=len(batches),
    )
