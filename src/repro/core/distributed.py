"""DisReduS / DisReduA — the paper's distributed reduction algorithms (§5).

Round structure (Algorithm 5.1):

  while global reduction progress:
      LocalReduce(G_i)            — §5.1, vectorized rule sweeps to fixpoint
      ExchWeightUpdates + ExchStatusUpdates — one fused halo exchange
      (FilterMoves is a no-op here: the static-shape adaptation resolves the
       move cases via degree-one folds and Lemma 4.4 tie-breaking; DESIGN.md §2)

DisReduA (§5.4) is realised as *bounded staleness*: instead of waiting for
the local fixpoint, each PE exchanges after `stale_sweeps` rule sweeps.
That is the paper's asynchrony insight — don't serialize on quiescence;
trade message freshness against idle time — mapped onto SPMD collectives,
where XLA overlaps the independent interior sweeps with collective latency.

Two execution paths share all rule/exchange code:

  * union path   — all PEs stacked into one block-diagonal graph on one
    device (exact SPMD simulation; tests/benches on CPU),
  * shard_map path — PE axis = mesh devices, lax collectives (production,
    and the lowering target of the multi-pod dry-run).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as E
from repro.core import exchange as X
from repro.core import rules as R
from repro.core.local_reduce import local_reduce
from repro.core.partition import PartitionedGraph

UNDECIDED, INCLUDED, EXCLUDED, FOLDED = 0, 1, 2, 3


def shard_map_compat(f, mesh, in_specs, out_specs, check_vma: bool = False):
    """jax.shard_map across jax versions (new API vs jax.experimental)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


@dataclasses.dataclass(frozen=True)
class DisReduConfig:
    heavy_k: int = 8
    use_heavy: bool = True
    mode: str = "sync"            # "sync" = DisReduS | "async" = DisReduA
    stale_sweeps: int = 2         # async: sweeps between exchanges
    exchange: str = "allgather"   # "allgather" | "a2a"  (shard_map path)
    schedule: str = "cheap"       # named rule schedule (engine.SCHEDULES)
    backend: str = "jnp"          # aggregate backend: jnp | blocked | pallas
    max_rounds: int = 10_000
    r_blk: Optional[int] = None   # blocked-ELL row-block height; None =
                                  # autotune at plan-build time (engine)
    # --- shape-descent policy (solvers.solve_staged) ------------------- #
    descent: bool = False         # re-pack the alive kernel onto smaller
                                  # ladder cells at stage boundaries
    descent_every: int = 2        # rounds (reduce/greedy) per stage between
                                  # descent checks
    descent_factor: int = 2       # hysteresis: only descend onto a cell
                                  # with cell.L * factor <= current L

    @property
    def sweeps_per_round(self) -> int:
        return 1_000_000 if self.mode == "sync" else self.stale_sweeps


class UnionProblem(NamedTuple):
    w0: jax.Array
    is_local: jax.Array
    is_ghost: jax.Array
    aux: R.Aux
    halo: X.Halo
    p: int
    V: int  # per-PE vertex count (union total = p * V)
    plan: Optional[E.SegPlan] = None  # blocked-ELL packing (non-jnp backends)


def build_union_problem(
    pg: PartitionedGraph, backend: str = "jnp",
    r_blk: Optional[int] = None,
    plan_cache: Optional[E.PlanCache] = None,
    plan_tag: Optional[str] = None,
) -> UnionProblem:
    """Stack all PEs into one block-diagonal graph with offset indices.

    ``plan_cache`` (an :class:`repro.core.engine.PlanCache`) reuses the
    blocked-ELL SegPlan across calls whenever the union topology repeats —
    plan packing and window-payload construction are the dominant host cost
    for repeated instances, so callers that solve the same graph shape many
    times (the serving layer, round-robin benches) should share one cache.
    """
    p, V = pg.p, pg.V
    off_v = (np.arange(p, dtype=np.int64) * V)[:, None]

    def offset_idx(a: np.ndarray) -> np.ndarray:
        # per-PE local indices -> union indices (nil_i = i*V + nil)
        return (a.astype(np.int64) + off_v.reshape((p,) + (1,) * (a.ndim - 1))).astype(np.int32)

    row = offset_idx(pg.row).reshape(-1)
    col = offset_idx(pg.col).reshape(-1)
    window = offset_idx(pg.window).reshape(p * V, -1)
    edge_common = offset_idx(pg.edge_common).reshape(row.shape[0], -1)
    aux = R.Aux(
        row=jnp.asarray(row), col=jnp.asarray(col),
        gid=jnp.asarray(pg.gid.reshape(-1)),
        is_local=jnp.asarray(pg.is_local.reshape(-1)),
        is_iface=jnp.asarray(pg.is_iface.reshape(-1)),
        owner_rank=jnp.asarray(pg.owner_pe.reshape(-1)),
        window=jnp.asarray(window),
        win_complete=jnp.asarray(pg.win_complete.reshape(-1)),
        win_adj_bits=jnp.asarray(pg.win_adj_bits.reshape(p * V, -1)),
        edge_common=jnp.asarray(edge_common),
    )
    halo = X.make_halo(pg, pe=None)
    plan = None if backend == "jnp" else E.plan_for(
        plan_cache, row, p * V, r_blk=r_blk,
        col=col, gid=pg.gid.reshape(-1), window=window,
        win_adj_bits=pg.win_adj_bits.reshape(p * V, -1),
        tag=plan_tag,
    )
    return UnionProblem(
        w0=jnp.asarray(pg.w0.reshape(-1)),
        is_local=jnp.asarray(pg.is_local.reshape(-1)),
        is_ghost=jnp.asarray(pg.is_ghost.reshape(-1)),
        aux=aux, halo=halo, p=p, V=V, plan=plan,
    )


# --------------------------------------------------------------------- #
# union path (single-device SPMD simulation)
# --------------------------------------------------------------------- #
def _round_union(state, prob: UnionProblem, cfg: DisReduConfig):
    state = local_reduce(
        state, prob.aux, heavy_k=cfg.heavy_k, use_heavy=cfg.use_heavy,
        max_sweeps=cfg.sweeps_per_round, schedule=cfg.schedule,
        backend=cfg.backend, plan=prob.plan,
    )
    state, _ = X.exchange_union(
        state, prob.aux, prob.halo, p=prob.p,
        backend=cfg.backend, plan=prob.plan,
    )
    return state


@functools.partial(
    jax.jit,
    static_argnames=("heavy_k", "use_heavy", "sweeps", "max_rounds", "p",
                     "schedule", "backend"),
)
def _disredu_union_jit(
    w0, is_local, is_ghost, aux, halo, plan, *, heavy_k, use_heavy, sweeps,
    max_rounds, p, schedule="cheap", backend="jnp"
):
    prob = UnionProblem(w0, is_local, is_ghost, aux, halo, p, 0, plan)
    cfg = DisReduConfig(
        heavy_k=heavy_k, use_heavy=use_heavy,
        mode="sync" if sweeps >= 1_000_000 else "async",
        stale_sweeps=sweeps, max_rounds=max_rounds, schedule=schedule,
        backend=backend,
    )
    state0 = R.init_state(w0, is_local, is_ghost)

    def body(carry):
        state, rounds, _ = carry
        snap_s, snap_w = state.status, state.w
        state = _round_union(state, prob, cfg)
        changed = (state.status != snap_s).any() | (state.w != snap_w).any()
        return state, rounds + 1, changed

    def cond(carry):
        _, rounds, changed = carry
        return changed & (rounds < max_rounds)

    state, rounds, _ = jax.lax.while_loop(
        cond, body, (state0, jnp.zeros((), jnp.int32), jnp.ones((), bool))
    )
    return state, rounds


def disredu(
    pg: PartitionedGraph, cfg: DisReduConfig = DisReduConfig()
) -> Tuple[R.RedState, UnionProblem, int]:
    """Run DisReduS/DisReduA on the union simulation path."""
    prob = build_union_problem(pg, cfg.backend, cfg.r_blk)
    state, rounds = _disredu_union_jit(
        prob.w0, prob.is_local, prob.is_ghost, prob.aux, prob.halo,
        prob.plan,
        heavy_k=cfg.heavy_k, use_heavy=cfg.use_heavy,
        sweeps=cfg.sweeps_per_round, max_rounds=cfg.max_rounds, p=prob.p,
        schedule=cfg.schedule, backend=cfg.backend,
    )
    return state, prob, int(rounds)


# --------------------------------------------------------------------- #
# shard_map path (production; also the dry-run lowering target)
# --------------------------------------------------------------------- #
def shard_map_arrays(pg: PartitionedGraph, cfg: DisReduConfig):
    """The stacked [p, ...] host arrays a shard_map driver consumes — the
    partitioned graph plus, for non-jnp backends, the per-PE blocked-ELL
    plan (packed host-side with a shared E_BLK so it meshes-shards)."""
    arrs = dict(pg.device_arrays())
    if cfg.backend != "jnp":
        if pg.row is None:
            raise ValueError(
                "backend=%r needs concrete edge arrays to pack the "
                "blocked-ELL plan; abstract (dry-run) graphs must use the "
                "jnp backend" % (cfg.backend,)
            )
        plan = E.build_plan_stacked(
            pg.row, pg.V, r_blk=cfg.r_blk,
            cols=pg.col, gids=pg.gid, windows=pg.window,
            win_adj_bits=pg.win_adj_bits,
        )
        arrs["plan_perm"] = np.asarray(plan.edge_perm)
        arrs["plan_lrow"] = np.asarray(plan.lrow)
        arrs["plan_wbits"] = np.asarray(plan.wbits)
        arrs["plan_wnh"] = np.asarray(plan.wnh)
        arrs["plan_rblk"] = np.zeros(
            (pg.p, plan.r_blk, 0), dtype=np.int32
        )
    return arrs


def _unpack_per_pe(pg: PartitionedGraph, keys, args):
    """Squeeze the leading PE axis and rebuild (aux, halo, plan, a)."""
    a = dict(zip(keys, [x.reshape(x.shape[1:]) for x in args]))
    aux = R.Aux(
        row=a["row"], col=a["col"], gid=a["gid"], is_local=a["is_local"],
        is_iface=a["is_iface"], owner_rank=a["owner_pe"],
        window=a["window"], win_complete=a["win_complete"],
        win_adj_bits=a["win_adj_bits"], edge_common=a["edge_common"],
    )
    L, G = pg.L, pg.G
    halo = X.Halo(
        iface_slots=a["iface_slots"],
        ghost_vertex=L + jnp.arange(G, dtype=jnp.int32),
        ghost_owner_pe=jnp.maximum(a["owner_pe"][L : L + G], 0),
        ghost_owner_slot=a["ghost_owner_slot"],
        ghost_valid=a["is_ghost"][L : L + G],
        send_slot=a["send_slot"], recv_ghost=a["recv_ghost"],
    )
    plan = (
        E.SegPlan(
            edge_perm=a["plan_perm"], lrow=a["plan_lrow"],
            rblk_tpl=a["plan_rblk"], wbits=a["plan_wbits"],
            wnh=a["plan_wnh"],
        )
        if "plan_perm" in a else None
    )
    return aux, halo, plan, a


def disredu_shard_map_fn(pg: PartitionedGraph, cfg: DisReduConfig, mesh,
                         axis: str = "pe"):
    """Return a jit-able function over stacked [p, ...] arrays running the
    full DisRedu round loop under shard_map on `mesh` (axis name `axis`)."""
    from jax.sharding import PartitionSpec as P

    arrs = shard_map_arrays(pg, cfg)
    keys = list(arrs.keys())

    def per_pe(*args):
        aux, halo, plan, a = _unpack_per_pe(pg, keys, args)
        state0 = R.init_state(a["w0"], a["is_local"], a["is_ghost"])

        def body(carry):
            state, rounds, _ = carry
            snap_s, snap_w = state.status, state.w
            state = local_reduce(
                state, aux, heavy_k=cfg.heavy_k, use_heavy=cfg.use_heavy,
                max_sweeps=cfg.sweeps_per_round, schedule=cfg.schedule,
                backend=cfg.backend, plan=plan,
            )
            state, _ = X.exchange_shmap(
                state, aux, halo, axis=axis, method=cfg.exchange,
                backend=cfg.backend, plan=plan,
            )
            local_changed = (
                (state.status != snap_s).any() | (state.w != snap_w).any()
            )
            changed = jax.lax.psum(local_changed.astype(jnp.int32), axis) > 0
            return state, rounds + 1, changed

        def cond(carry):
            _, rounds, changed = carry
            return changed & (rounds < cfg.max_rounds)

        state, rounds, _ = jax.lax.while_loop(
            cond, body,
            (state0, jnp.zeros((), jnp.int32), jnp.ones((), bool)),
        )
        ex = lambda a: a.reshape((1,) + a.shape)
        return ex(state.w), ex(state.status), ex(state.log_kind), \
            ex(state.log_v), ex(state.log_u), ex(state.log_n), \
            ex(state.offset), ex(rounds)

    in_specs = tuple(P(axis) for _ in keys)
    out_specs = (P(axis),) * 8
    fn = shard_map_compat(per_pe, mesh, in_specs, out_specs)

    def run(arrays=None):
        arrays = arrays if arrays is not None else \
            {k: jnp.asarray(v) for k, v in arrs.items()}
        return fn(*(arrays[k] for k in keys))

    return run, keys


# --------------------------------------------------------------------- #
# result extraction
# --------------------------------------------------------------------- #
def kernel_stats(
    pg: PartitionedGraph, state: R.RedState
) -> Tuple[int, int]:
    """(#alive vertices, #alive undirected edges) of the reduced graph."""
    status = np.asarray(state.status)
    is_local = np.asarray(pg.is_local.reshape(-1))
    alive_v = int(((status == UNDECIDED) & is_local).sum())
    row = np.asarray(pg.row).astype(np.int64)
    col = np.asarray(pg.col).astype(np.int64)
    off = (np.arange(pg.p, dtype=np.int64) * pg.V)[:, None]
    ur, uc = (row + off).reshape(-1), (col + off).reshape(-1)
    ea = (status[ur] == UNDECIDED) & (status[uc] == UNDECIDED)
    loc = np.asarray(pg.is_local.reshape(-1))
    # count each undirected edge once: local rows only, and only (u < v) by gid
    gids = np.asarray(pg.gid.reshape(-1))
    cnt = int((ea & loc[ur] & (gids[ur] < gids[uc])).sum())
    return alive_v, cnt


def kernel_shape(pg: PartitionedGraph, status: np.ndarray) -> dict:
    """Exact per-PE padded-size requirements of the alive kernel.

    Returns the smallest L/G/E/B/S a :func:`partition.compact_partition`
    restriction of ``pg`` at this state needs (maxima over PEs, before any
    ladder-cell flooring).  This is the stage-boundary measurement the
    shape-descent policy compares against the static cell ladder.
    """
    p, V, L, G = pg.p, pg.V, pg.L, pg.G
    status = np.asarray(status).reshape(p, V)
    alive = status == UNDECIDED
    keep_l = pg.is_local & alive
    keep_g = pg.is_ghost & alive
    keep = keep_l | keep_g
    nl = ng = ne = nb = ns = 0
    for i in range(p):
        nl = max(nl, int(keep_l[i].sum()))
        ng = max(ng, int(keep_g[i].sum()))
        ne = max(ne, int((keep[i][pg.row[i]] & keep[i][pg.col[i]]).sum()))
        nb = max(nb, int((keep_l[i] & pg.is_iface[i]).sum()))
        gk = np.flatnonzero(keep_g[i])
        if gk.size:
            owners = pg.owner_pe[i, gk]
            ns = max(ns, int(np.bincount(owners[owners >= 0]).max()))
    return dict(L=nl, G=ng, E=ne, B=nb, S=ns)


def ghosts_consistent(pg: PartitionedGraph, status: np.ndarray) -> bool:
    """True iff every valid ghost slot is alive exactly when its owner's
    local copy is alive — the exchange-consistency precondition of
    :func:`partition.compact_partition`.  Holds at every post-exchange
    round boundary; transiently false between a peel and the next
    exchange (the staged solver never descends there)."""
    p, V = pg.p, pg.V
    status = np.asarray(status).reshape(p, V)
    alive = status == UNDECIDED
    owner_alive = np.zeros(pg.n_global, dtype=bool)
    for i in range(p):
        loc = pg.is_local[i]
        owner_alive[pg.gid[i][loc]] = alive[i][loc]
    for i in range(p):
        gh = pg.is_ghost[i]
        if (alive[i][gh] != owner_alive[pg.gid[i][gh]]).any():
            return False
    return True


def state_template(union_v: int) -> R.RedState:
    """A zero :class:`RedState` with the union-layout shapes for ``p*V =
    union_v`` slots — the restore template for checkpointed stage states
    (shape-descent checkpoints store one state per descent level, each at
    its own ladder shape; the level's V is recorded in the checkpoint
    manifest)."""
    z = jnp.zeros(union_v, jnp.int32)
    return R.init_state(z, jnp.zeros(union_v, bool), jnp.zeros(union_v, bool))


def members_global(
    pg: PartitionedGraph, state: R.RedState, aux: R.Aux
) -> np.ndarray:
    """Reconstruct and assemble the global member mask (union layout)."""
    in_set = np.asarray(R.reconstruct_members(state, aux))
    members = np.zeros(pg.n_global, dtype=bool)
    is_local = np.asarray(pg.is_local.reshape(-1))
    gids = np.asarray(pg.gid.reshape(-1))
    sel = in_set & is_local
    members[gids[sel]] = True
    return members
