"""MWIS solver driver — the paper's workload end to end.

    PYTHONPATH=src python -m repro.launch.mwis_run \
        --family rhg --n 20000 --p 8 --algo rnp --mode async

Generates (or loads) an instance, partitions it with halos, runs the chosen
distributed solver on the union simulation path (single device) or the
shard_map path (with REPRO_PE_DEVICES host devices), verifies independence
and reports quality vs the sequential baseline.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", default="rhg",
                    choices=("rhg", "rgg", "gnm"))
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--p", type=int, default=8)
    ap.add_argument("--algo", default="rnp",
                    choices=("reduce", "greedy", "rg", "rnp"))
    ap.add_argument("--mode", default="async", choices=("sync", "async"))
    ap.add_argument("--exchange", default="allgather",
                    choices=("allgather", "a2a"))
    ap.add_argument("--window-cap", type=int, default=16)
    ap.add_argument("--heavy-k", type=int, default=8)
    ap.add_argument("--schedule", default="cheap",
                    help="named rule schedule (repro.core.engine.SCHEDULES)")
    ap.add_argument("--backend", default="jnp",
                    choices=("jnp", "blocked", "pallas"),
                    help="aggregate backend for the rule-test reductions")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compare-seq", action="store_true")
    ap.add_argument("--bfs-relabel", action="store_true",
                    help="locality relabel (partitioning variant, Table C.3)")
    args = ap.parse_args()

    from repro.core import distributed as D, partition as part, solvers as S
    from repro.graphs import generators as gen
    from repro.graphs.relabel import relabel_bfs

    g = gen.FAMILIES[args.family](args.n, seed=args.seed)
    if args.bfs_relabel:
        g = relabel_bfs(g)
    print(f"instance: {args.family} n={g.n} m={g.m}")
    t0 = time.time()
    pg = part.partition_graph(g, args.p, window_cap=args.window_cap)
    print(f"partition: p={args.p} L={pg.L} G={pg.G} E={pg.E} "
          f"B={pg.B} ({time.time() - t0:.2f}s)")
    cfg = D.DisReduConfig(
        heavy_k=args.heavy_k, mode=args.mode, exchange=args.exchange,
        schedule=args.schedule, backend=args.backend,
    )

    if args.algo == "reduce":
        t0 = time.time()
        state, prob, rounds = D.disredu(pg, cfg)
        dt = time.time() - t0
        nv, ne = D.kernel_stats(pg, state)
        print(f"DisRedu{'A' if args.mode == 'async' else 'S'}: "
              f"rounds={rounds} time={dt:.2f}s "
              f"|V'|/|V|={nv / g.n:.4f} |E'|/|E|={ne / max(g.m, 1):.4f} "
              f"offset={int(state.offset)}")
        return

    t0 = time.time()
    members, state = S.solve(pg, args.algo, cfg)
    dt = time.time() - t0
    assert g.is_independent_set(members), "solution must be independent!"
    w = g.set_weight(members)
    print(f"{args.algo}/{args.mode}: weight={w} |I|={members.sum()} "
          f"time={dt:.2f}s")

    if args.compare_seq:
        from repro.core import sequential as seq

        t0 = time.time()
        w_seq, _ = seq.solve_reduce_and_peel(g)
        print(f"sequential RnP baseline: weight={w_seq} "
              f"time={time.time() - t0:.2f}s quality={w / max(w_seq, 1):.4f}")


if __name__ == "__main__":
    main()
