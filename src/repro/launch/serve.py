"""Serving driver: batched DLRM scoring or LM decode on reduced configs.

    PYTHONPATH=src python -m repro.launch.serve --arch dlrm-mlperf --requests 8
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --tokens 16
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dlrm-mlperf")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import common as MC

    if args.arch == "dlrm-mlperf":
        from repro.configs.dlrm_mlperf import SMOKE as cfg
        from repro.data.pipeline import DLRMBatchSpec, dlrm_batch
        from repro.models import dlrm as M

        params = MC.init_params(M.param_specs(cfg), jax.random.key(0))
        serve = jax.jit(lambda p, b: M.serve_step(p, b, cfg))
        spec = DLRMBatchSpec(args.batch, cfg.n_dense, cfg.n_sparse,
                             cfg.vocabs)
        lat = []
        for r in range(args.requests):
            b = dlrm_batch(spec, r)
            b.pop("labels")
            t0 = time.perf_counter()
            probs = serve(params, {k: jnp.asarray(v) for k, v in b.items()})
            probs.block_until_ready()
            lat.append((time.perf_counter() - t0) * 1e3)
            print(f"request {r}: batch={args.batch} "
                  f"mean_ctr={float(probs.mean()):.4f} "
                  f"lat={lat[-1]:.2f}ms")
        lat = np.asarray(lat[1:])  # drop compile
        print(f"p50={np.percentile(lat, 50):.2f}ms "
              f"p99={np.percentile(lat, 99):.2f}ms")
        return

    # LM decode
    from repro.configs import gemma3_1b, mistral_nemo_12b, qwen3_32b

    smokes = {
        "gemma3-1b": gemma3_1b.SMOKE,
        "qwen3-32b": qwen3_32b.SMOKE,
        "mistral-nemo-12b": mistral_nemo_12b.SMOKE,
    }
    cfg = smokes[args.arch]
    from repro.models import transformer as T

    params = MC.init_params(T.param_specs(cfg), jax.random.key(0))
    B, S = args.batch, args.tokens + 8
    (kc_abs, vc_abs), _ = T.make_kv_cache_specs(cfg, B, S)
    kc = jnp.zeros(kc_abs.shape, kc_abs.dtype)
    vc = jnp.zeros(vc_abs.shape, vc_abs.dtype)

    decode = jax.jit(
        lambda p, kc, vc, tok, pos: T.serve_step(p, (kc, vc), tok, pos, cfg)
    )
    tok = jnp.zeros((B, 1), jnp.int32)
    t0 = time.perf_counter()
    for t in range(args.tokens):
        logits, (kc, vc) = decode(params, kc, vc, tok,
                                  jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens x batch {B} in {dt:.2f}s "
          f"({args.tokens * B / dt:.1f} tok/s, incl. compile)")


if __name__ == "__main__":
    main()
