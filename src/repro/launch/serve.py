"""Serving driver: batched MWIS solving, DLRM scoring, or LM decode.

The default ``mwis`` arch drives the batched many-instance front end
(:mod:`repro.core.serve`): a stream of random instances is bucketed into
the static serve cells, topology-cached, and solved as vmapped batches;
the driver reports sustained instances/sec, p50/p99 batch latency, and
plan-cache statistics.

    PYTHONPATH=src python -m repro.launch.serve --arch mwis --requests 64
    PYTHONPATH=src python -m repro.launch.serve --arch mwis --algo rnp \\
        --backend blocked --batch 16 --repeat-topologies 4
    PYTHONPATH=src python -m repro.launch.serve --arch dlrm-mlperf --requests 8
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --tokens 16
"""

from __future__ import annotations

import argparse
import time

ARCHES = ("mwis", "dlrm-mlperf", "gemma3-1b", "qwen3-32b",
          "mistral-nemo-12b")


def _serve_mwis(args) -> None:
    import jax
    import numpy as np

    from repro.core import serve as SV
    from repro.graphs.generators import gnm

    cfg = SV.ServeConfig(algo=args.algo, backend=args.backend,
                         max_batch=args.batch, verify=args.verify,
                         descent=args.descent, devices=args.devices,
                         pipeline=not args.no_pipeline)
    try:
        svc = SV.MWISService(cfg)
    except ValueError as e:
        import sys

        print(f"error: {e}", file=sys.stderr)
        raise SystemExit(2)
    cells = svc.cells
    ndev = svc.stats["devices"]
    print(f"mwis service: algo={cfg.algo} backend={cfg.backend} "
          f"verify={cfg.verify} descent={cfg.descent} "
          f"batch<={cfg.max_batch} cells="
          f"{[f'{c.name}(L={c.L},E={c.E})' for c in cells]}")
    print(f"devices: {ndev}/{jax.device_count()} visible "
          f"({jax.default_backend()}) "
          f"pipeline={'on' if cfg.pipeline else 'off'}")

    # instance stream: cycle the cells, repeat each topology a few times
    # (fresh weights each request — the production re-auction pattern)
    rng = np.random.default_rng(args.seed)
    reqs = []
    topo = 0
    while len(reqs) < args.requests:
        cell = cells[topo % len(cells)]
        n = int(cell.L * 0.8)
        m = min(2 * n, cell.E // 4)
        g = gnm(n, m, seed=args.seed + topo)
        for _ in range(args.repeat_topologies):
            w = rng.integers(1, 201, size=g.n).astype(np.int32)
            reqs.append(type(g)(indptr=g.indptr, indices=g.indices,
                                weights=w))
            if len(reqs) == args.requests:
                break
        topo += 1

    batches = [reqs[i:i + args.batch]
               for i in range(0, len(reqs), args.batch)]
    stats = SV.measure_throughput(svc, batches, warmup=1)
    tot_w = 0
    n_err = 0
    for b in batches:
        rs = svc.solve_batch(list(b))
        tot_w += sum(r.weight for r in rs)
        n_err += sum(not r.ok for r in rs)
    print(f"requests={stats['instances']} batches={stats['batches']} "
          f"throughput={stats['instances_per_sec']:.1f} inst/s")
    print(f"p50={stats['p50_ms']:.2f}ms p99={stats['p99_ms']:.2f}ms "
          f"(per-batch latency)")
    print(f"total solution weight (last pass): {tot_w} "
          f"({n_err} per-request errors)")
    s = svc.stats
    print(f"cache: hits={s['cache_hits']} misses={s['cache_misses']} "
          f"evictions={s['cache_evictions']} errors={s['cache_errors']} "
          f"size={s['cache_size']} programs={s['programs']} "
          f"compiles={s['compiles']}")
    print(f"robustness: backend={s['backend']}"
          f"{'' if s['backend_active'] == s['backend'] else ' -> ' + s['backend_active']} "
          f"rejected={s['rejected']} repaired={s['repaired']} "
          f"pack_errors={s['pack_errors']} solve_errors={s['solve_errors']} "
          f"fallbacks={s['fallbacks']} "
          f"verified={s['verify_checked']}/{s['verify_failures']} "
          f"(checked/failed)")
    print(f"descent: mode={cfg.descent} "
          f"solves={s['descent_solves']} descents={s['descents']} "
          f"oversize_admitted={s['oversize_admitted']} "
          f"plan_cache_hits={s['cache_descent_hits']}/"
          f"{s['cache_descent_hits'] + s['cache_descent_misses']}")
    p50 = s["stage_p50_ms"]
    print(f"stages (p50/chunk): pack={p50['pack']:.2f}ms "
          f"transfer={p50['transfer']:.2f}ms solve={p50['solve']:.2f}ms "
          f"fetch={p50['fetch']:.2f}ms")
    print(f"pipeline: devices={s['devices']} chunks={s['chunks']} "
          f"pipelined={s['pipelined_chunks']} "
          f"retries={s['pipeline_retries']} "
          f"overlap_ratio={s['overlap_ratio']:.3f}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="mwis", choices=ARCHES)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    # mwis-only knobs
    ap.add_argument("--algo", default="rg",
                    choices=("greedy", "rg", "rnp"))
    ap.add_argument("--backend", default="jnp",
                    choices=("jnp", "blocked", "pallas"))
    ap.add_argument("--repeat-topologies", type=int, default=4,
                    help="requests sharing one topology (fresh weights)")
    ap.add_argument("--verify", default="off",
                    choices=("off", "sample", "full"),
                    help="post-solve output audit (independence + weight)")
    ap.add_argument("--descent", default="off", choices=("off", "auto"),
                    help="shape descent: big cells shrink mid-solve and "
                         "oversize instances enter via descent cells")
    ap.add_argument("--devices", type=int, default=None,
                    help="serve-mesh size for the sharded batch axis "
                         "(default: every visible device; exits with an "
                         "error when more are requested than exist)")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="disable the overlapped host pack/transfer "
                         "pipeline (chunks run synchronously)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.arch == "mwis":
        _serve_mwis(args)
        return

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import common as MC

    if args.arch == "dlrm-mlperf":
        from repro.configs.dlrm_mlperf import SMOKE as cfg
        from repro.data.pipeline import DLRMBatchSpec, dlrm_batch
        from repro.models import dlrm as M

        params = MC.init_params(M.param_specs(cfg), jax.random.key(0))
        serve = jax.jit(lambda p, b: M.serve_step(p, b, cfg))
        spec = DLRMBatchSpec(args.batch, cfg.n_dense, cfg.n_sparse,
                             cfg.vocabs)
        lat = []
        for r in range(args.requests):
            b = dlrm_batch(spec, r)
            b.pop("labels")
            t0 = time.perf_counter()
            probs = serve(params, {k: jnp.asarray(v) for k, v in b.items()})
            probs.block_until_ready()
            lat.append((time.perf_counter() - t0) * 1e3)
            print(f"request {r}: batch={args.batch} "
                  f"mean_ctr={float(probs.mean()):.4f} "
                  f"lat={lat[-1]:.2f}ms")
        lat = np.asarray(lat[1:])  # drop compile
        print(f"p50={np.percentile(lat, 50):.2f}ms "
              f"p99={np.percentile(lat, 99):.2f}ms")
        return

    # LM decode
    from repro.configs import gemma3_1b, mistral_nemo_12b, qwen3_32b

    smokes = {
        "gemma3-1b": gemma3_1b.SMOKE,
        "qwen3-32b": qwen3_32b.SMOKE,
        "mistral-nemo-12b": mistral_nemo_12b.SMOKE,
    }
    cfg = smokes[args.arch]
    from repro.models import transformer as T

    params = MC.init_params(T.param_specs(cfg), jax.random.key(0))
    B, S = args.batch, args.tokens + 8
    (kc_abs, vc_abs), _ = T.make_kv_cache_specs(cfg, B, S)
    kc = jnp.zeros(kc_abs.shape, kc_abs.dtype)
    vc = jnp.zeros(vc_abs.shape, vc_abs.dtype)

    decode = jax.jit(
        lambda p, kc, vc, tok, pos: T.serve_step(p, (kc, vc), tok, pos, cfg)
    )
    tok = jnp.zeros((B, 1), jnp.int32)
    t0 = time.perf_counter()
    for t in range(args.tokens):
        logits, (kc, vc) = decode(params, kc, vc, tok,
                                  jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens x batch {B} in {dt:.2f}s "
          f"({args.tokens * B / dt:.1f} tok/s, incl. compile)")


if __name__ == "__main__":
    main()
