"""Production meshes.

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — the `pod` axis
composes with data parallelism (hierarchical gradient reduction) and with
the PE axis for MWIS/GNN graph partitioning.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_pe_mesh(base_mesh):
    """Flatten a production mesh into a single 'pe' axis (MWIS runs)."""
    devs = base_mesh.devices.reshape(-1)
    return jax.sharding.Mesh(devs, ("pe",))


def make_host_mesh(p: int):
    """Small test mesh over host CPU devices (requires XLA_FLAGS set)."""
    devs = np.asarray(jax.devices()[:p])
    return jax.sharding.Mesh(devs, ("pe",))
