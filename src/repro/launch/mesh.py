"""Production meshes.

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — the `pod` axis
composes with data parallelism (hierarchical gradient reduction) and with
the PE axis for MWIS/GNN graph partitioning.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_pe_mesh(base_mesh):
    """Flatten a production mesh into a single 'pe' axis (MWIS runs)."""
    devs = base_mesh.devices.reshape(-1)
    return jax.sharding.Mesh(devs, ("pe",))


def make_host_mesh(p: int):
    """Small test mesh over host CPU devices (requires XLA_FLAGS set)."""
    devs = np.asarray(jax.devices()[:p])
    return jax.sharding.Mesh(devs, ("pe",))


def make_serve_mesh(num_devices: int | None = None):
    """Flatten the visible devices into a 1-D ``serve`` mesh — the batch
    axis of the serving layer (repro.core.serve shards each stacked chunk
    across it).  ``num_devices`` caps the mesh to the first N devices;
    ``None`` takes every visible one.  CPU tests get multiple devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before jax
    import).  Raises when more devices are requested than exist."""
    devs = jax.devices()
    if num_devices is not None:
        if not 1 <= num_devices <= len(devs):
            raise ValueError(
                f"make_serve_mesh: requested {num_devices} device(s) but "
                f"only {len(devs)} visible — launch with more devices or "
                f"set XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{num_devices} for CPU testing"
            )
        devs = devs[:num_devices]
    return jax.sharding.Mesh(np.asarray(devs), ("serve",))
