"""Training driver: any --arch on real (small) or abstract (dry-run) scale.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
        --smoke-scale --steps 100 --ckpt /tmp/ckpt

On this CPU container only reduced configs actually step (--smoke-scale);
full configs belong to the dry-run (launch/dryrun.py).  The loop runs under
TrainSupervisor: checkpoint cadence, restart-resume, straggler flags.
"""

from __future__ import annotations

import argparse
import dataclasses
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--smoke-scale", action="store_true", default=True)
    args = ap.parse_args()

    import importlib

    import jax
    import numpy as np

    from repro.configs import registry
    from repro.data.pipeline import LMBatchSpec, lm_batch
    from repro.distributed.checkpoint import CheckpointManager
    from repro.distributed.fault import TrainSupervisor
    from repro.models import common as MC
    from repro.models import transformer as T
    from repro.train import optimizer as opt

    arch = registry.get(args.arch)
    assert arch.family == "lm", "train.py drives LM archs; see mwis_run.py"
    mod = importlib.import_module(
        registry.get(args.arch).build.func.__module__
    ) if False else None
    # reduced config from the arch module
    from repro.configs import (gemma3_1b, grok1_314b, mistral_nemo_12b,
                               qwen3_32b, qwen3_moe_235b)

    smokes = {
        "gemma3-1b": gemma3_1b.SMOKE,
        "qwen3-32b": qwen3_32b.SMOKE,
        "qwen3-moe-235b-a22b": qwen3_moe_235b.SMOKE,
        "grok-1-314b": grok1_314b.SMOKE,
        "mistral-nemo-12b": mistral_nemo_12b.SMOKE,
    }
    cfg = dataclasses.replace(smokes[args.arch], loss_chunks=2)
    print(f"training {cfg.name} (reduced): {cfg.n_params() / 1e6:.2f}M params")

    specs = T.param_specs(cfg)
    params = MC.init_params(specs, jax.random.key(0))
    ostate = opt.adamw_init(params)
    ocfg = opt.AdamWConfig(lr=3e-4)
    bspec = LMBatchSpec(args.batch, args.seq, cfg.vocab)

    @jax.jit
    def step_fn(params, ostate, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, batch, cfg)
        )(params)
        params, ostate = opt.adamw_update(grads, ostate, params, ocfg)
        return loss, params, ostate

    cm = CheckpointManager(args.ckpt, keep=2)
    sup = TrainSupervisor(cm, save_every=args.save_every)

    state = {"params": params, "opt": ostate}

    def one(state, step):
        batch = {k: jax.numpy.asarray(v)
                 for k, v in lm_batch(bspec, step).items()}
        loss, p2, o2 = step_fn(state["params"], state["opt"], batch)
        if step % 10 == 0:
            print(f"step {step}: loss={float(loss):.4f}", flush=True)
        return {"params": p2, "opt": o2}

    t0 = time.time()
    state = sup.run(state, one, args.steps, state_template=state)
    print(f"done: {args.steps} steps in {time.time() - t0:.1f}s; "
          f"events={sup.events}")


if __name__ == "__main__":
    main()
