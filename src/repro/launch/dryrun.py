import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede every other import (jax locks the device
count at first init): the dry-run — and only the dry-run — sees 512
placeholder host devices so `jax.make_mesh` can build the production mesh.

Per cell we record:
  * compile success,
  * `compiled.memory_analysis()`  (per-device bytes — proves it fits),
  * `compiled.cost_analysis()`    (FLOPs / bytes for §Roofline),
  * collective bytes parsed from the lowered HLO (§Roofline third term),
  * the three roofline terms + bottleneck (analysis/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # orchestrates subprocesses
  python -m repro.launch.dryrun --list
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")


PROBE_LAYER_FIELD = {
    "lm": "n_layers", "gnn": None, "recsys": None, "mwis": None,
}


def _probe_overrides(arch, shape):
    """Per-family probe configs: (list of (tag, overrides, layer_count),
    full_layer_count).  Scans are fully unrolled in probes so
    cost_analysis counts true work; two layer counts -> linear fit."""
    fam = arch.family
    if fam == "lm":
        ov = {"probe_unroll": True}
        if shape == "prefill_32k":
            ov["attn_chunk"] = 8192   # 4x4 attention tiles, unrolled exactly
        if shape == "train_4k":
            ov["attn_chunk"] = 1024   # 4x4 tiles
        return ([("p2", dict(ov, n_layers=2), 2),
                 ("p4", dict(ov, n_layers=4), 4)], None)
    if fam == "gnn":
        if arch.arch_id == "graphsage-reddit":
            return ([("p1", {}, None)], None)  # python loops: already exact
        field = "n_blocks" if arch.arch_id == "dimenet" else "n_layers"
        ov = {"probe_unroll": True}
        if arch.arch_id == "equiformer-v2":
            ov["edge_chunk"] = 1 << 62  # single edge chunk (flops invariant)
        return ([("p2", dict(ov, **{field: 2}), 2),
                 ("p4", dict(ov, **{field: 4}), 4)], field)
    if fam == "recsys":
        return ([("p1", {}, None)], None)      # no loops: already exact
    # mwis: loop-free single sweep-round probe
    return ([("sweep", {"probe": True}, None)], None)


def run_cell(arch_id: str, shape: str, mesh_kind: str,
             xla_opts: str = "", overrides=None) -> dict:
    import jax

    from repro.analysis import hlo as hlo_mod
    from repro.analysis import roofline as rl
    from repro.configs import base as cbase
    from repro.configs import registry
    from repro.launch.mesh import make_pe_mesh, make_production_mesh

    t0 = time.time()
    arch = registry.get(arch_id)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(mesh.devices.size)
    if arch.family == "mwis":
        mesh = make_pe_mesh(mesh)
    from repro.models import common as MC

    MC.set_hint_mesh(mesh)
    fsdp = cbase.fsdp_axes_for(mesh) or ("pe",)

    built = arch.build(shape, mesh, fsdp, overrides) if overrides else \
        arch.build(shape, mesh, fsdp)
    kw = {}
    if built.out_shardings is not None:
        kw["out_shardings"] = built.out_shardings
    jitted = jax.jit(built.fn, in_shardings=built.in_shardings, **kw)
    lowered = jitted.lower(*built.abstract_inputs)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    print("memory_analysis:", mem)
    print("cost_analysis[flops]:", cost.get("flops"),
          "bytes:", cost.get("bytes accessed"))

    text = compiled.as_text()
    coll = hlo_mod.collective_bytes(text)
    roof = rl.from_cell(cost, coll, built.model_flops, n_chips)

    return dict(
        arch=arch_id, shape=shape, mesh=mesh_kind, n_chips=n_chips,
        ok=True,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            code_bytes=mem.generated_code_size_in_bytes,
        ),
        cost=dict(
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        ),
        collectives=coll,
        roofline=roof.report(),
        note=built.note,
        xla_opts=xla_opts,
        overrides={k: str(v) for k, v in (overrides or {}).items()},
    )


def all_cells():
    from repro.configs import registry

    cells = []
    for arch_id, shape, skip in registry.all_cells(include_skipped=False):
        for mesh_kind in ("single", "multi"):
            cells.append((arch_id, shape, mesh_kind))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=ARTIFACTS)
    ap.add_argument("--tag", default="", help="artifact filename suffix "
                    "(perf-iteration variants)")
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--probe", action="store_true",
                    help="unrolled-scan probe compiles for exact roofline")
    ap.add_argument("--override", action="append", default=[],
                    help="config override key=value (perf variants)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)

    if args.list:
        for c in all_cells():
            print(*c)
        return

    if args.all:
        cells = all_cells()
        failures = 0
        for arch_id, shape, mesh_kind in cells:
            tag = f"_{args.tag}" if args.tag else ""
            fn = os.path.join(
                args.out, f"{arch_id}__{shape}__{mesh_kind}{tag}.json"
            )
            if os.path.exists(fn) and not args.force:
                print(f"[skip] {fn}")
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch_id, "--shape", shape, "--mesh", mesh_kind,
                "--out", args.out,
            ] + (["--tag", args.tag] if args.tag else [])
            print(f"[cell] {arch_id} × {shape} × {mesh_kind} ...",
                  flush=True)
            try:
                r = subprocess.run(
                    cmd, capture_output=True, text=True,
                    timeout=args.timeout,
                )
                if r.returncode != 0:
                    failures += 1
                    err = dict(arch=arch_id, shape=shape, mesh=mesh_kind,
                               ok=False, error=r.stderr[-4000:])
                    with open(fn, "w") as f:
                        json.dump(err, f, indent=1)
                    print(f"  FAILED (see {fn})")
                else:
                    print("  ok")
            except subprocess.TimeoutExpired:
                failures += 1
                with open(fn, "w") as f:
                    json.dump(dict(arch=arch_id, shape=shape, mesh=mesh_kind,
                                   ok=False, error="timeout"), f)
                print("  TIMEOUT")
        print(f"dry-run complete; {failures} failures")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape, "--arch and --shape required"
    cli_ov = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            cli_ov[k] = json.loads(v)
        except json.JSONDecodeError:
            cli_ov[k] = v
    if args.probe:
        from repro.configs import registry as _reg

        arch = _reg.get(args.arch)
        probes, field = _probe_overrides(arch, args.shape)
        for ptag, ov, layers in probes:
            ov = dict(ov, **cli_ov)
            if args.tag:
                ptag = f"{ptag}_{args.tag}" 
            fn = os.path.join(
                args.out,
                f"{args.arch}__{args.shape}__{args.mesh}_probe{ptag}.json",
            )
            if os.path.exists(fn) and not args.force:
                print(f"[skip] {fn}")
                continue
            try:
                rec = run_cell(args.arch, args.shape, args.mesh,
                               overrides=ov)
                rec["probe_layers"] = layers
            except Exception:
                traceback.print_exc()
                rec = dict(arch=args.arch, shape=args.shape, mesh=args.mesh,
                           ok=False, probe=ptag,
                           error=traceback.format_exc()[-4000:])
            with open(fn, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"[probe {ptag}] written {fn}")
        return
    tag = f"_{args.tag}" if args.tag else ""
    fn = os.path.join(
        args.out, f"{args.arch}__{args.shape}__{args.mesh}{tag}.json"
    )
    try:
        rec = run_cell(args.arch, args.shape, args.mesh,
                       overrides=cli_ov or None)
    except Exception:
        traceback.print_exc()
        with open(fn, "w") as f:
            json.dump(dict(arch=args.arch, shape=args.shape, mesh=args.mesh,
                           ok=False, error=traceback.format_exc()[-4000:]),
                      f, indent=1)
        sys.exit(1)
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec["roofline"], indent=1))


if __name__ == "__main__":
    main()
