"""repro: KaDisRedu-JAX — distributed reductions for Maximum Weight Independent Set.

A JAX/TPU framework reproducing and extending
"Distributed Reductions for the Maximum Weight Independent Set Problem"
(Borowitz, Großmann, Schimek — CS.DC 2025).

Layers
------
core/         the paper's contribution: distributed reduction model, rules,
              DisReduS/DisReduA, reduce-and-greedy / reduce-and-peel solvers
graphs/       instance generators (GNM / RGG / RHG) and neighbor sampling
models/       assigned architectures (LM transformers, GNNs, DLRM)
kernels/      Pallas TPU kernels with jnp oracles
distributed/  sharding, checkpointing, fault tolerance, compression
launch/       production mesh, multi-pod dry-run, train/serve drivers
analysis/     HLO collective parsing + roofline
"""

__version__ = "1.0.0"
