"""Fault tolerance & elasticity for 1000+-node deployments.

What actually fails at scale and what this module does about it:

  * **Node loss** — training: checkpoint/restart is the recovery primitive
    (atomic-commit checkpoints in `checkpoint.py`; `TrainSupervisor` wraps
    the step loop with save cadence + restore-on-restart + deterministic
    data-skip so restarts replay no batch twice).  MWIS: the reduction
    state (w, status, fold log, offset) *is* the checkpoint — rounds are
    idempotent from any consistent state, so restart = reload + continue.
  * **Stragglers** — DisReduA's bounded-staleness exchange already removes
    the per-round straggler barrier for MWIS (a slow PE delays neighbors by
    at most one halo exchange, not the whole fixpoint).  For training, the
    supervisor tracks a rolling step-time EWMA and flags outliers
    (`straggler_factor`) — the deployment hook decides to re-shard or evict.
  * **Elastic scaling** — `remesh_plan` recomputes the vertex partition for
    a new p and maps old→new PE state; checkpoints are stored logically
    (unsharded) so parameter state re-shards by construction
    (`CheckpointManager.restore(shardings=new)`).
  * **Chaos engineering** — :class:`FaultPlan` + :func:`run_union_reduction`
    are the deterministic fault-injection harness for the DisRedu exchange
    loop: a seeded plan delays or drops one PE's halo board for k rounds
    (a straggler / lost message under bounded staleness, §5.4), corrupts a
    weight plane (bit-rot on the wire or in memory), or kills the run
    mid-sweep (node loss).  The harness drives the round loop from the
    host through the :func:`repro.core.exchange.union_boards` /
    ``reconcile_union_boards`` seam, checks the reduction monotonicity
    invariants every round (weights never increase; decided vertices never
    revert to UNDECIDED — exactly why stale boards are safe, Lemma 4.2),
    and checkpoints `RedState` so restart-from-checkpoint is bit-identical
    to an uninterrupted run (`tests/test_chaos.py` proves both).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.distributed.checkpoint import CheckpointManager


@dataclasses.dataclass
class StragglerMonitor:
    """Rolling EWMA of step times; flags steps slower than factor×EWMA."""

    alpha: float = 0.1
    factor: float = 2.0
    ewma: Optional[float] = None
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.factor * self.ewma
        self.ewma = dt if self.ewma is None else (
            (1 - self.alpha) * self.ewma + self.alpha * dt
        )
        if slow:
            self.flagged += 1
        return slow


class TrainSupervisor:
    """Checkpoint-cadenced, restart-safe step loop driver.

    The data pipeline must be indexable by step (deterministic): on restore
    the loop resumes at `start_step` without replaying batches.
    """

    def __init__(
        self,
        ckpt: CheckpointManager,
        *,
        save_every: int = 100,
        straggler: Optional[StragglerMonitor] = None,
    ):
        self.ckpt = ckpt
        self.save_every = save_every
        self.straggler = straggler or StragglerMonitor()
        self.events: list = []

    def resume_step(self) -> int:
        latest = self.ckpt.latest_step()
        return 0 if latest is None else latest + 1

    def run(
        self,
        state: Any,
        step_fn: Callable[[Any, int], Any],
        n_steps: int,
        *,
        state_template: Optional[Any] = None,
    ) -> Any:
        start = self.resume_step()
        if start > 0:
            state = self.ckpt.restore(state_template or state)
            self.events.append(("restored", start - 1))
        for step in range(start, n_steps):
            t0 = time.monotonic()
            state = step_fn(state, step)
            dt = time.monotonic() - t0
            if self.straggler.observe(dt):
                self.events.append(("straggler", step, dt))
            if (step + 1) % self.save_every == 0 or step == n_steps - 1:
                self.ckpt.save(step, state)
        self.ckpt.wait()
        return state


# --------------------------------------------------------------------- #
# deterministic fault injection for the DisRedu exchange loop
# --------------------------------------------------------------------- #
class InjectedFault(RuntimeError):
    """Raised by :func:`run_union_reduction` at a FaultPlan kill point."""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded, fully deterministic fault schedule for one reduction run.

    Rounds are 0-based indices of the harness round loop.  A PE index of
    ``-1`` (or a round of ``-1``) disables that fault.  All faults compose.

      * delay — PE ``delay_pe``'s published board lags ``delay_rounds``
        rounds behind, starting at round ``delay_from`` (a straggler under
        bounded staleness: neighbors keep reducing on stale-but-valid
        upper bounds, Lemma 4.2).
      * drop — PE ``drop_pe``'s board is not delivered at all for
        ``drop_rounds`` rounds from ``drop_from`` (lost messages: receivers
        keep the last board they saw).
      * corrupt — at round ``corrupt_round``, one of PE ``corrupt_pe``'s
        local weights is bumped *up* by a seeded amount — a monotonicity
        violation the harness's invariant checker must flag.
      * kill — :class:`InjectedFault` is raised at the start of round
        ``kill_round`` (mid-sweep node loss; recover via checkpoints).
    """

    seed: int = 0
    delay_pe: int = -1
    delay_rounds: int = 0
    delay_from: int = 0
    drop_pe: int = -1
    drop_rounds: int = 0
    drop_from: int = 0
    corrupt_pe: int = -1
    corrupt_round: int = -1
    kill_round: int = -1

    @staticmethod
    def random_delay(seed: int, p: int, *, max_delay: int = 3) -> "FaultPlan":
        """Seeded straggler plan: one random PE, random lag/onset."""
        rng = np.random.default_rng(seed)
        return FaultPlan(
            seed=seed,
            delay_pe=int(rng.integers(0, p)),
            delay_rounds=int(rng.integers(1, max_delay + 1)),
            delay_from=int(rng.integers(0, 3)),
        )


def _round_fns(backend: str):
    """Jitted (sweep, boards, reconcile) round pieces, cached per backend."""
    import jax

    from repro.core import exchange as X
    from repro.core.local_reduce import local_reduce

    @functools.partial(
        jax.jit,
        static_argnames=("heavy_k", "use_heavy", "sweeps", "schedule"),
    )
    def sweep_fn(state, aux, plan, *, heavy_k, use_heavy, sweeps, schedule):
        return local_reduce(
            state, aux, heavy_k=heavy_k, use_heavy=use_heavy,
            max_sweeps=sweeps, schedule=schedule, backend=backend, plan=plan,
        )

    @jax.jit
    def boards_fn(state, halo):
        return X.union_boards(state, halo)

    @jax.jit
    def reconcile_fn(state, aux, halo, bw, bs, plan):
        return X.reconcile_union_boards(
            state, aux, halo, bw, bs, backend=backend, plan=plan,
        )

    return sweep_fn, boards_fn, reconcile_fn


@functools.lru_cache(maxsize=8)
def _round_fns_cached(backend: str):
    return _round_fns(backend)


def run_union_reduction(
    prob,
    cfg,
    *,
    faults: Optional[FaultPlan] = None,
    state=None,
    start_round: int = 0,
    max_rounds: Optional[int] = None,
    ckpt: Optional[CheckpointManager] = None,
    save_every: int = 1,
    check_invariants: bool = True,
) -> Tuple[Any, int, Dict[str, Any]]:
    """Host-driven DisRedu round loop with deterministic fault injection.

    Semantically the same reduction as ``distributed._disredu_union_jit``
    (local_reduce → halo exchange → repeat until no global change), but the
    round loop runs on the host through the exchange board seam so faults
    can be injected *between* board publication and delivery — exactly
    where a real deployment loses or delays messages.  Each round is a
    deterministic function of ``state`` alone, so a run restored from a
    `RedState` checkpoint is bit-identical to an uninterrupted one.

    Args:
      prob: a ``UnionProblem`` (``distributed.build_union_problem``).
      cfg: a ``DisReduConfig`` (schedule/backend/sweeps as usual).
      faults: optional :class:`FaultPlan`; None runs fault-free.
      state: resume state (e.g. a restored checkpoint); None starts fresh.
      start_round: round index to resume at (fault rounds are absolute).
      ckpt: optional :class:`CheckpointManager`; saves `RedState` every
        ``save_every`` completed rounds (atomic commit, integrity-hashed).
      check_invariants: verify per round that weights never increase and
        decided vertices never revert (violations recorded, not raised).

    Returns ``(state, rounds_done, report)`` where report carries
    ``fixpoint`` (bool), ``events`` (applied faults), and ``violations``
    (invariant breaches, e.g. from an injected weight corruption).
    """
    from repro.core import rules as R

    fp = faults or FaultPlan()
    limit = cfg.max_rounds if max_rounds is None else max_rounds
    sweep_fn, boards_fn, reconcile_fn = _round_fns_cached(cfg.backend)
    if state is None:
        state = R.init_state(prob.w0, prob.is_local, prob.is_ghost)

    V = prob.V if prob.V else prob.w0.shape[0] // prob.p
    events: List[tuple] = []
    violations: List[tuple] = []
    # hist[0] = boards of the entry state; hist[t+1] = boards published in
    # round (start_round + t).  Resumed runs rebuild history lazily — a
    # delay fault reaching past the resume point sees the entry boards,
    # the most conservative (stalest) legal message.
    hist: List[tuple] = [boards_fn(state, prob.halo)]
    rounds = 0
    fixpoint = False

    for t in range(start_round, start_round + limit):
        if t == fp.kill_round:
            events.append(("killed", t))
            raise InjectedFault(f"FaultPlan kill at round {t}")
        snap_w = np.asarray(state.w)
        snap_status = np.asarray(state.status)

        state = sweep_fn(
            state, prob.aux, prob.plan,
            heavy_k=cfg.heavy_k, use_heavy=cfg.use_heavy,
            sweeps=cfg.sweeps_per_round, schedule=cfg.schedule,
        )

        if fp.corrupt_pe >= 0 and t == fp.corrupt_round:
            rng = np.random.default_rng(fp.seed)
            # corrupt a *local* slot (ghost slots are re-clamped by the
            # owner's board on reconcile — min() would mask the fault) and
            # bump past the round-entry maximum: weights only ever
            # decrease, so this is an unambiguous monotonicity breach
            lo, hi = fp.corrupt_pe * V, (fp.corrupt_pe + 1) * V
            local = np.flatnonzero(
                np.asarray(prob.is_local).reshape(-1)[lo:hi])
            idx = lo + int(local[rng.integers(0, local.size)])
            bump = int(snap_w.max()) + int(rng.integers(1, 1000))
            state = state._replace(w=state.w.at[idx].add(bump))
            events.append(("corrupted", t, fp.corrupt_pe, idx, bump))

        bw, bs = boards_fn(state, prob.halo)
        hist.append((bw, bs))
        eff_w, eff_s = bw, bs
        hi = len(hist) - 1  # index of this round's boards
        if fp.delay_pe >= 0 and fp.delay_rounds > 0 and t >= fp.delay_from:
            src_w, src_s = hist[max(0, hi - fp.delay_rounds)]
            eff_w = eff_w.at[fp.delay_pe].set(src_w[fp.delay_pe])
            eff_s = eff_s.at[fp.delay_pe].set(src_s[fp.delay_pe])
            events.append(("delayed", t, fp.delay_pe))
        if (fp.drop_pe >= 0
                and fp.drop_from <= t < fp.drop_from + fp.drop_rounds):
            # receivers keep the last board delivered before the outage
            src_w, src_s = hist[max(0, fp.drop_from - start_round)]
            eff_w = eff_w.at[fp.drop_pe].set(src_w[fp.drop_pe])
            eff_s = eff_s.at[fp.drop_pe].set(src_s[fp.drop_pe])
            events.append(("dropped", t, fp.drop_pe))

        state, _ = reconcile_fn(
            state, prob.aux, prob.halo, eff_w, eff_s, prob.plan
        )
        rounds += 1

        new_w = np.asarray(state.w)
        new_status = np.asarray(state.status)
        if check_invariants:
            up = new_w > snap_w
            if np.any(up):
                violations.append(
                    ("weight_increased", t, [int(i) for i in
                                             np.flatnonzero(up)[:8]])
                )
            revert = (snap_status != 0) & (new_status == 0)
            if np.any(revert):
                violations.append(
                    ("decided_reverted", t, [int(i) for i in
                                             np.flatnonzero(revert)[:8]])
                )

        if ckpt is not None and (rounds % max(save_every, 1) == 0):
            ckpt.save(t, state)
            ckpt.wait()

        changed = (not np.array_equal(new_status, snap_status)
                   or not np.array_equal(new_w, snap_w))
        # Bounded staleness: a stale board is eventually delivered, so the
        # loop may only declare fixpoint on an unchanged round whose
        # delivered boards equal the fresh ones.  While the state is
        # stable the lagged history catches up within delay_rounds rounds,
        # so this terminates — and it is exactly why delayed runs reach
        # the SAME fixpoint as fault-free ones (Lemma 4.2).
        fresh = (np.array_equal(np.asarray(eff_w), np.asarray(bw))
                 and np.array_equal(np.asarray(eff_s), np.asarray(bs)))
        if not changed and fresh:
            fixpoint = True
            break

    report = dict(fixpoint=fixpoint, events=events, violations=violations)
    return state, rounds, report


def remesh_plan(n_global: int, p_old: int, p_new: int) -> Dict[str, Any]:
    """Vertex-block mapping for elastic MWIS re-partitioning.

    Contiguous blocks make elastic remaps pure interval arithmetic: each new
    PE's block is covered by a small set of old-PE intervals.  Returns, for
    every new PE, the (old_pe, old_lo, old_hi, new_lo) copy descriptors a
    deployment would turn into point-to-point transfers.
    """
    old = np.linspace(0, n_global, p_old + 1).astype(np.int64)
    new = np.linspace(0, n_global, p_new + 1).astype(np.int64)
    plan = []
    for j in range(p_new):
        lo, hi = int(new[j]), int(new[j + 1])
        segs = []
        for i in range(p_old):
            a, b = max(lo, int(old[i])), min(hi, int(old[i + 1]))
            if a < b:
                segs.append(
                    dict(old_pe=i, old_lo=a - int(old[i]),
                         old_hi=b - int(old[i]), new_lo=a - lo, size=b - a)
                )
        plan.append(segs)
    return {"p_old": p_old, "p_new": p_new, "copies": plan}
