"""Fault tolerance & elasticity for 1000+-node deployments.

What actually fails at scale and what this module does about it:

  * **Node loss** — training: checkpoint/restart is the recovery primitive
    (atomic-commit checkpoints in `checkpoint.py`; `TrainSupervisor` wraps
    the step loop with save cadence + restore-on-restart + deterministic
    data-skip so restarts replay no batch twice).  MWIS: the reduction
    state (w, status, fold log, offset) *is* the checkpoint — rounds are
    idempotent from any consistent state, so restart = reload + continue.
  * **Stragglers** — DisReduA's bounded-staleness exchange already removes
    the per-round straggler barrier for MWIS (a slow PE delays neighbors by
    at most one halo exchange, not the whole fixpoint).  For training, the
    supervisor tracks a rolling step-time EWMA and flags outliers
    (`straggler_factor`) — the deployment hook decides to re-shard or evict.
  * **Elastic scaling** — `remesh_plan` recomputes the vertex partition for
    a new p and maps old→new PE state; checkpoints are stored logically
    (unsharded) so parameter state re-shards by construction
    (`CheckpointManager.restore(shardings=new)`).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.distributed.checkpoint import CheckpointManager


@dataclasses.dataclass
class StragglerMonitor:
    """Rolling EWMA of step times; flags steps slower than factor×EWMA."""

    alpha: float = 0.1
    factor: float = 2.0
    ewma: Optional[float] = None
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.factor * self.ewma
        self.ewma = dt if self.ewma is None else (
            (1 - self.alpha) * self.ewma + self.alpha * dt
        )
        if slow:
            self.flagged += 1
        return slow


class TrainSupervisor:
    """Checkpoint-cadenced, restart-safe step loop driver.

    The data pipeline must be indexable by step (deterministic): on restore
    the loop resumes at `start_step` without replaying batches.
    """

    def __init__(
        self,
        ckpt: CheckpointManager,
        *,
        save_every: int = 100,
        straggler: Optional[StragglerMonitor] = None,
    ):
        self.ckpt = ckpt
        self.save_every = save_every
        self.straggler = straggler or StragglerMonitor()
        self.events: list = []

    def resume_step(self) -> int:
        latest = self.ckpt.latest_step()
        return 0 if latest is None else latest + 1

    def run(
        self,
        state: Any,
        step_fn: Callable[[Any, int], Any],
        n_steps: int,
        *,
        state_template: Optional[Any] = None,
    ) -> Any:
        start = self.resume_step()
        if start > 0:
            state = self.ckpt.restore(state_template or state)
            self.events.append(("restored", start - 1))
        for step in range(start, n_steps):
            t0 = time.monotonic()
            state = step_fn(state, step)
            dt = time.monotonic() - t0
            if self.straggler.observe(dt):
                self.events.append(("straggler", step, dt))
            if (step + 1) % self.save_every == 0 or step == n_steps - 1:
                self.ckpt.save(step, state)
        self.ckpt.wait()
        return state


def remesh_plan(n_global: int, p_old: int, p_new: int) -> Dict[str, Any]:
    """Vertex-block mapping for elastic MWIS re-partitioning.

    Contiguous blocks make elastic remaps pure interval arithmetic: each new
    PE's block is covered by a small set of old-PE intervals.  Returns, for
    every new PE, the (old_pe, old_lo, old_hi, new_lo) copy descriptors a
    deployment would turn into point-to-point transfers.
    """
    old = np.linspace(0, n_global, p_old + 1).astype(np.int64)
    new = np.linspace(0, n_global, p_new + 1).astype(np.int64)
    plan = []
    for j in range(p_new):
        lo, hi = int(new[j]), int(new[j + 1])
        segs = []
        for i in range(p_old):
            a, b = max(lo, int(old[i])), min(hi, int(old[i + 1]))
            if a < b:
                segs.append(
                    dict(old_pe=i, old_lo=a - int(old[i]),
                         old_hi=b - int(old[i]), new_lo=a - lo, size=b - a)
                )
        plan.append(segs)
    return {"p_old": p_old, "p_new": p_new, "copies": plan}
