"""Gradient compression for cross-pod data parallelism.

At 2+ pods the gradient all-reduce crosses the slow inter-pod links; the
standard mitigations implemented here:

  * int8 quantization with per-tensor scale + **error feedback** (the
    quantization residual is carried into the next step, preserving
    convergence — Seide et al. / EF-SGD),
  * top-k sparsification with error feedback (bandwidth ∝ k),
  * hierarchical schedule helper: reduce-scatter intra-pod (fast ICI),
    all-reduce only the 1/N_pod shard across pods, all-gather intra-pod —
    expressed as the axis ordering the train step passes to `psum`.

These transforms are pure jnp (jit-safe) and compose with `shard_map`.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any  # same pytree as grads


def ef_init(grads_like: Any) -> EFState:
    return EFState(
        residual=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
        )
    )


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_int8_ef(grads: Any, ef: EFState) -> Tuple[Any, Any, EFState]:
    """Returns (quantized pytree, scales pytree, new EF state)."""

    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        return q, s, x - deq

    qs, ss, rs = [], [], []
    leaves, td = jax.tree.flatten(grads)
    for g, r in zip(leaves, jax.tree.leaves(ef.residual)):
        q, s, nr = one(g, r)
        qs.append(q); ss.append(s); rs.append(nr)
    uf = lambda xs: jax.tree.unflatten(td, xs)
    return uf(qs), uf(ss), EFState(residual=uf(rs))


def topk_ef(grads: Any, ef: EFState, k_frac: float = 0.01):
    """Top-k magnitude sparsification with error feedback."""

    def one(g, r):
        x = (g.astype(jnp.float32) + r).reshape(-1)
        k = max(1, int(x.shape[0] * k_frac))
        vals, idx = jax.lax.top_k(jnp.abs(x), k)
        kept = x[idx]
        sparse = jnp.zeros_like(x).at[idx].set(kept)
        return (idx, kept), x - sparse

    outs, rs = [], []
    leaves, td = jax.tree.flatten(grads)
    for g, r in zip(leaves, jax.tree.leaves(ef.residual)):
        o, nr = one(g, r)
        outs.append(o); rs.append(nr.reshape(g.shape))
    uf = lambda xs: jax.tree.unflatten(td, xs)
    return uf(outs), EFState(residual=uf(rs))


def hierarchical_psum(x: jax.Array, *, pod_axis: str = "pod",
                      data_axis: str = "data") -> jax.Array:
    """Reduce-scatter intra-pod → cross-pod psum on the shard → all-gather.

    Inside shard_map over a ("pod", "data", ...) mesh this is the
    bandwidth-optimal hierarchy: the slow inter-pod link carries 1/|data|
    of the gradient bytes.
    """
    n = jax.lax.axis_size(data_axis)
    pad = (-x.shape[0]) % n
    xp = jnp.pad(x.reshape(-1), (0, x.size % 1 + pad))[: x.size + pad]
    shard = jax.lax.psum_scatter(
        xp.reshape(n, -1), data_axis, scatter_dimension=0, tiled=False
    )
    shard = jax.lax.psum(shard, pod_axis)
    full = jax.lax.all_gather(shard, data_axis, tiled=False)
    return full.reshape(-1)[: x.size].reshape(x.shape)
