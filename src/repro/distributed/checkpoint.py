"""Sharded checkpointing with integrity manifests + async commit.

Format (directory per step):

    step_000123/
      manifest.json      — tree structure, shapes, dtypes, shard files,
                           content hashes, mesh shape, framework version
      <leafpath>.npy     — one file per pytree leaf (per-host shard in a
                           true multi-host deployment; whole array here)

Fault-tolerance properties:

  * atomic commit — written to ``<dir>.tmp`` then renamed; a crash mid-write
    never corrupts the latest checkpoint (restore scans for the newest
    *committed* step),
  * integrity — SHA256 per leaf, verified on restore,
  * async mode  — device→host transfer happens synchronously (cheap), disk
    write runs on a background thread so the train loop continues
    (`wait()` joins before the next save),
  * elastic restore — leaves are saved unsharded-logical; restoring onto a
    different mesh/process count just re-shards via `jax.device_put` with
    the new sharding (see `restore(..., shardings=)`).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _leaf_paths(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_leaf_paths(tree[k], f"{prefix}{k}."))
    elif hasattr(tree, "_fields"):  # NamedTuple — before the tuple branch,
        # so leaf keys are field names (what _unflatten_like looks up)
        for k in tree._fields:
            out.update(_leaf_paths(getattr(tree, k), f"{prefix}{k}."))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_leaf_paths(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = tree
    return out


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3, async_write: bool = True):
        self.root = root
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------- #
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
        self.wait()
        leaves = _leaf_paths(tree)
        host = {k: np.asarray(v) for k, v in leaves.items()}

        def write():
            tmp = os.path.join(self.root, f"step_{step:09d}.tmp")
            final = os.path.join(self.root, f"step_{step:09d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {
                "step": step, "extra": extra or {}, "leaves": {},
            }
            for k, arr in host.items():
                fn = k.replace("/", "_") + ".npy"
                np.save(os.path.join(tmp, fn), arr)
                manifest["leaves"][k] = {
                    "file": fn,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
                }
            with open(os.path.join(tmp, _MANIFEST), "w") as f:
                json.dump(manifest, f, indent=1)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
        return os.path.join(self.root, f"step_{step:09d}")

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- #
    def list_steps(self):
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, d, _MANIFEST)):
                    out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def manifest(self, step: Optional[int] = None) -> Dict:
        """The committed manifest for `step` (default: latest).

        Exposes ``extra`` metadata without touching array shards — restore
        flows whose *templates* depend on saved metadata (e.g. the staged
        solver's per-descent-level state shapes) read this first, build
        shape-correct templates, then call :meth:`restore`.
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        assert step is not None, "no committed checkpoint found"
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, _MANIFEST)) as f:
            return json.load(f)

    def restore(
        self, template: Any, step: Optional[int] = None, *,
        shardings: Any = None, verify: bool = True,
    ) -> Any:
        """Restore into the structure of `template`; optionally re-shard
        (elastic restart onto a different mesh)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        assert step is not None, "no committed checkpoint found"
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, _MANIFEST)) as f:
            manifest = json.load(f)
        leaves = _leaf_paths(template)
        shard_leaves = _leaf_paths(shardings) if shardings is not None else {}
        out: Dict[str, Any] = {}
        for k in leaves:
            meta = manifest["leaves"][k]
            arr = np.load(os.path.join(d, meta["file"]))
            if verify:
                h = hashlib.sha256(arr.tobytes()).hexdigest()
                if h != meta["sha256"]:
                    raise IOError(f"checkpoint leaf {k} failed integrity check")
            if k in shard_leaves:
                arr = jax.device_put(arr, shard_leaves[k])
            out[k] = arr
        return _unflatten_like(template, out)


def _unflatten_like(template: Any, flat: Dict[str, Any], prefix: str = ""):
    if isinstance(template, dict):
        return {
            k: _unflatten_like(template[k], flat, f"{prefix}{k}.")
            for k in template
        }
    if isinstance(template, (list, tuple)) and not hasattr(template, "_fields"):
        t = type(template)
        return t(
            _unflatten_like(v, flat, f"{prefix}{i}.")
            for i, v in enumerate(template)
        )
    if hasattr(template, "_fields"):
        vals = {
            k: _unflatten_like(getattr(template, k), flat, f"{prefix}{k}.")
            for k in template._fields
        }
        return type(template)(**vals)
    return flat[prefix[:-1]]
