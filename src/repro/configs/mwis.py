"""mwis — the paper's own workload as a selectable architecture.

Shapes follow the paper's weak-scaling setup (§7: N = 2^20 vertices and
M = 2^22 edges per core, growing with p) plus a strong-scaling RnP cell.
The PE axis is the flattened production mesh (pod × data × model).

Every shape cell carries a named **rule schedule** (an
``repro.core.engine.SCHEDULES`` key) consumed by the reduction drivers:
the weak-scaling reduce cells run the fused hot path ("cheap-fused"), the
RnP cell runs the cheaper windowless schedule ("edges-only") between
peels.  Override per run with ``overrides={"schedule": ..., "backend":
..., "seg_blk": {...}}``; backends pick the segment-reduction
implementation (jnp portable, pallas blocked-ELL on TPU) and ``seg_blk``
the per-cell blocked-ELL block sizes (see ``base.MWIS_SHAPES``).
"""

from __future__ import annotations

import functools

from repro.configs import base


def rule_schedule(shape_name: str) -> str:
    """The named rule schedule a shape cell reduces with."""
    return base.MWIS_SHAPES[shape_name].get("schedule", "cheap-fused")


def serve_knobs(shape_name: str) -> dict:
    """Per-cell multi-device serving knobs of a kind="serve" shape row:
    ``serve_devices`` caps the batch-axis mesh for the cell (None = whole
    serve mesh), ``pipeline`` opts the cell out of the overlapped host
    pack/transfer pipeline.  Consumed by repro.core.serve.ServeCell."""
    meta = base.MWIS_SHAPES[shape_name]
    return dict(serve_devices=meta.get("serve_devices"),
                pipeline=meta.get("pipeline", True))


def serve_cell_names() -> tuple:
    """The single-PE serving buckets (kind="serve") of MWIS_SHAPES, in
    ascending size order — the bucket table of the batched front end."""
    cells = [(name, meta) for name, meta in base.MWIS_SHAPES.items()
             if meta.get("kind") == "serve"]
    cells.sort(key=lambda kv: (kv[1]["L"], kv[1]["E"]))
    return tuple(name for name, _ in cells)


def smoke():
    from repro.configs.smoke_runners import mwis_smoke

    mwis_smoke()


def _build(shape_name, mesh, fsdp, overrides=None):
    return base.mwis_build(shape_name, mesh, fsdp, overrides)


ARCH = base.ArchDef(
    arch_id="mwis",
    family="mwis",
    # serve cells are single-PE buckets of the batched serving front
    # end (repro.core.serve) and descent cells are mid-solve re-pack rungs
    # (repro.core.solvers.solve_staged) — neither is a mesh dry-run workload
    shapes=tuple(s for s, m in base.MWIS_SHAPES.items()
                 if m.get("kind") not in ("serve", "descent")),
    build=_build,
    smoke=smoke,
)
