"""gatedgcn — 16L d_hidden=70 gated aggregator. [arXiv:2003.00982; paper]"""

from __future__ import annotations

import dataclasses
import functools

from repro.configs import base
from repro.models.gnn.gatedgcn import GatedGCNConfig
from repro.models.gnn import gatedgcn as module

CONFIG = GatedGCNConfig(n_layers=16, d_hidden=70)

SMOKE = dataclasses.replace(CONFIG, n_layers=3, d_hidden=16, n_classes=4)


def _flops(cfg, n, e2):
    per_node = 5 * 2 * cfg.d_hidden**2   # U,V,E1,E2,E3 matmuls
    per_edge = 6 * cfg.d_hidden
    return 3.0 * cfg.n_layers * (n * per_node + e2 * per_edge)


def smoke():
    from repro.configs.smoke_runners import gnn_smoke

    gnn_smoke(module, SMOKE, molecular=False)


ARCH = base.ArchDef(
    arch_id="gatedgcn",
    family="gnn",
    shapes=tuple(base.GNN_SHAPES),
    build=functools.partial(
        base.gnn_build, module, CONFIG, molecular=False, flops_fn=_flops
    ),
    smoke=smoke,
)
