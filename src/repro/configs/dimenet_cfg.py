"""dimenet — 6 blocks d_hidden=128 n_bilinear=8 spherical=7 radial=6.
[arXiv:2003.03123; unverified]
"""

from __future__ import annotations

import dataclasses
import functools

from repro.configs import base
from repro.models.gnn.dimenet import DimeNetConfig
from repro.models.gnn import dimenet as module

CONFIG = DimeNetConfig(
    n_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7, n_radial=6,
)

SMOKE = dataclasses.replace(CONFIG, n_blocks=2, d_hidden=16, n_bilinear=4,
                            n_spherical=3, n_radial=3)


def _flops(cfg, n, e2):
    t = 8 * e2  # capped triplet budget
    per_edge = 2 * cfg.d_hidden**2 + 2 * cfg.d_hidden * cfg.n_radial
    per_tri = 2 * cfg.d_hidden * cfg.n_bilinear
    per_node = 4 * cfg.d_hidden**2
    return 3.0 * cfg.n_blocks * (e2 * per_edge + t * per_tri + n * per_node)


def smoke():
    from repro.configs.smoke_runners import gnn_smoke

    gnn_smoke(module, SMOKE, molecular=True)


ARCH = base.ArchDef(
    arch_id="dimenet",
    family="gnn",
    shapes=tuple(base.GNN_SHAPES),
    build=functools.partial(
        base.gnn_build, module, CONFIG, molecular=True, flops_fn=_flops
    ),
    smoke=smoke,
)
