"""Arch registry: ``--arch <id>`` resolution for launchers and the dry-run."""

from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchDef


def _load() -> Dict[str, ArchDef]:
    from repro.configs import (
        dimenet_cfg, dlrm_mlperf, gatedgcn_cfg, gemma3_1b,
        graphsage_reddit, grok1_314b, mistral_nemo_12b, mwis,
        equiformer_v2_cfg, qwen3_32b, qwen3_moe_235b,
    )

    archs = [
        qwen3_moe_235b.ARCH, grok1_314b.ARCH, mistral_nemo_12b.ARCH,
        qwen3_32b.ARCH, gemma3_1b.ARCH,
        equiformer_v2_cfg.ARCH, dimenet_cfg.ARCH, gatedgcn_cfg.ARCH,
        graphsage_reddit.ARCH,
        dlrm_mlperf.ARCH,
        mwis.ARCH,
    ]
    return {a.arch_id: a for a in archs}


ARCHS = _load()


def get(arch_id: str) -> ArchDef:
    if arch_id not in ARCHS:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}"
        )
    return ARCHS[arch_id]


def all_cells(include_skipped: bool = False):
    """Every (arch, shape) dry-run cell; skipped cells annotated."""
    out = []
    for a in ARCHS.values():
        for s in a.shapes:
            out.append((a.arch_id, s, None))
        if include_skipped:
            for s, why in a.skips.items():
                out.append((a.arch_id, s, why))
    return out
