"""mistral-nemo-12b — 40L d_model=5120 32H (GQA kv=8, d_head=128)
d_ff=14336, vocab=131072, dense, 128k ctx.
[hf:mistralai/Mistral-Nemo-Base-2407; hf]
"""

from __future__ import annotations

import dataclasses
import functools

from repro.configs import base
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="mistral-nemo-12b",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=131072, rope_theta=1_000_000.0, attn_chunk=512,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=128, attn_chunk=32, loss_chunks=2,
)


def smoke():
    from repro.configs.smoke_runners import lm_smoke

    lm_smoke(SMOKE)


ARCH = base.ArchDef(
    arch_id="mistral-nemo-12b",
    family="lm",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    build=functools.partial(base.lm_build, CONFIG),
    smoke=smoke,
    skips={"long_500k": "pure full-attention arch (assignment rule)"},
)
