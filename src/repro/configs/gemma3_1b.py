"""gemma3-1b — 26L d_model=1152 4H (GQA kv=1, d_head=256) d_ff=6912,
vocab=262144, 5:1 local:global interleave (sliding window 512), 128k ctx.
[hf:google/gemma-3-1b-pt; unverified]

The hybrid local:global attention makes this the one assigned LM arch that
runs the `long_500k` cell (sub-quadratic in the local layers).
"""

from __future__ import annotations

import dataclasses
import functools

from repro.configs import base
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="gemma3-1b",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_head=256,
    d_ff=6912, vocab=262144, local_window=512, global_every=6,
    rope_theta=1_000_000.0, attn_chunk=512,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, d_head=16,
    d_ff=128, vocab=128, local_window=8, global_every=3, attn_chunk=16,
    loss_chunks=2,
)


def smoke():
    from repro.configs.smoke_runners import lm_smoke

    lm_smoke(SMOKE)


ARCH = base.ArchDef(
    arch_id="gemma3-1b",
    family="lm",
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    build=functools.partial(base.lm_build, CONFIG),
    smoke=smoke,
)
