"""qwen3-moe-235b-a22b — 94L d_model=4096 64H (GQA kv=4, d_head=128)
MoE 128 experts top-8 (expert d_ff=1536), vocab 151936, qk_norm.
[hf:Qwen/Qwen3-235B-A22B family; verified tier: hf]
"""

from __future__ import annotations

import dataclasses
import functools

from repro.configs import base
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab=151936, moe_experts=128, moe_top_k=8, qk_norm=True,
    rope_theta=1_000_000.0, attn_chunk=512,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=32, vocab=128, moe_experts=8, moe_top_k=2, attn_chunk=32,
    loss_chunks=2,
)


def smoke():
    from repro.configs.smoke_runners import lm_smoke

    lm_smoke(SMOKE)


ARCH = base.ArchDef(
    arch_id="qwen3-moe-235b-a22b",
    family="lm",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    build=functools.partial(base.lm_build, CONFIG),
    smoke=smoke,
    skips={"long_500k": "pure full-attention arch (assignment rule: "
                        "long_500k only for sub-quadratic attention)"},
)
