"""equiformer-v2 — 12L d_hidden=128 l_max=6 m_max=2 8 heads, SO(2)-eSCN
equivariant graph attention.  [arXiv:2306.12059; unverified]
"""

from __future__ import annotations

import dataclasses
import functools

from repro.configs import base
from repro.models.gnn.equiformer_v2 import EquiformerV2Config
from repro.models.gnn import equiformer_v2 as module

CONFIG = EquiformerV2Config(
    n_layers=12, d_hidden=128, l_max=6, m_max=2, n_heads=8,
)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_hidden=16, l_max=2,
                            m_max=1, n_heads=2, n_radial=4)


def _flops(cfg, n, e2):
    n_lm = cfg.lm_count
    per_edge = 2 * n_lm * cfg.d_hidden**2 + 3 * n_lm * cfg.d_hidden
    per_node = 2 * n_lm * cfg.d_hidden**2 + 2 * cfg.d_hidden**2
    return 3.0 * cfg.n_layers * (e2 * per_edge + n * per_node)


def smoke():
    from repro.configs.smoke_runners import gnn_smoke

    gnn_smoke(module, SMOKE, molecular=True)


ARCH = base.ArchDef(
    arch_id="equiformer-v2",
    family="gnn",
    shapes=tuple(base.GNN_SHAPES),
    build=functools.partial(
        base.gnn_build, module, CONFIG, molecular=True, flops_fn=_flops
    ),
    smoke=smoke,
)
