"""Config/arch plumbing: every assigned architecture registers an ArchDef
whose `build(shape, mesh, fsdp)` returns the jit-able step function, the
abstract inputs (ShapeDtypeStructs — no allocation), and in_shardings for
the multi-pod dry-run.  The same ArchDef supplies a reduced smoke config
that actually runs one step on CPU.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import common as MC
from repro.train import optimizer as opt


# --------------------------------------------------------------------- #
# LM shape cells (seq_len × global_batch; decode shapes lower serve_step)
# --------------------------------------------------------------------- #
LM_SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

GNN_SHAPES: Dict[str, Dict[str, Any]] = {
    "full_graph_sm": dict(kind="train", n_nodes=2708, n_edges=10556,
                          d_feat=1433, n_graphs=1),
    "minibatch_lg": dict(kind="train", n_nodes=169984, n_edges=168960,
                         d_feat=602, n_graphs=1, sampled=True,
                         batch_nodes=1024, fanout=(15, 10)),
    "ogb_products": dict(kind="train", n_nodes=2449029, n_edges=61859140,
                         d_feat=100, n_graphs=1),
    "molecule": dict(kind="train", n_nodes=3840, n_edges=8192, d_feat=16,
                     n_graphs=128),
}

RECSYS_SHAPES: Dict[str, Dict[str, Any]] = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}

# the paper's own workload as an 11th selectable arch (PE-flattened mesh).
# `schedule` names the rule schedule (repro.core.engine.SCHEDULES) each cell
# runs per round: the weak-scaling reduce cells take the fused hot path;
# the RnP cell runs the cheaper windowless schedule between peels.
# `seg_blk` is the per-cell blocked-ELL autotune table consumed at
# plan-build time (engine.build_plan): `r_blk` fixes the row-block height
# (None → measure-free autotune over engine.R_BLK_CANDIDATES); the edge
# budget E_BLK follows from the packing, rounded up to engine.E_BLK_MULTIPLE
# sublanes.  Weak-scaling cells are E/L = 8 with GNM-like degree skew, where
# taller blocks average out the per-block edge-count max that sets E_BLK
# (see BENCH_engine.json's per-candidate rows); the RnP strong-scaling cell
# sweeps a shrinking kernel, where the smallest block wins.
MWIS_SHAPES: Dict[str, Dict[str, Any]] = {
    # weak-scaling cells (paper §7): per-PE vertices/edges as on HoreKa
    "weak_1m": dict(kind="reduce", L=1 << 20, E=1 << 23, G=1 << 16,
                    B=1 << 16, S=1 << 10, D=16, Dc=4,
                    schedule="cheap-fused", seg_blk=dict(r_blk=32)),
    "weak_4m": dict(kind="reduce", L=1 << 22, E=1 << 25, G=1 << 17,
                    B=1 << 17, S=1 << 11, D=16, Dc=4,
                    schedule="cheap-fused", seg_blk=dict(r_blk=32)),
    "strong_128m": dict(kind="rnp", L=1 << 18, E=1 << 21, G=1 << 15,
                        B=1 << 15, S=1 << 10, D=16, Dc=4,
                        schedule="edges-only", seg_blk=dict(r_blk=8)),
    # serving cells (MWIS-as-a-service): single-PE buckets the batched
    # front end pads small/medium instances into.  An incoming instance
    # lands in the smallest cell with L >= n and E >= 2m, so every
    # (cell, batch-size) pair is ONE compiled program.  G/B/S are the
    # min_pad floors (p=1 has no halo); D is the serve window cap;
    # seg_blk fixes the blocked-ELL row-block height per cell (batching
    # requires one shared r_blk) and e_blk floors the shared edge budget
    # (the serving layer grows it as a high-water mark).  serve_devices
    # caps how many mesh devices the cell's batch axis is sharded over
    # (None = whole serve mesh) and pipeline opts the cell out of the
    # overlapped host pack/transfer pipeline (both consumed by
    # repro.core.serve through the ServeCell rows).
    "serve_xs": dict(kind="serve", L=64, E=1024, G=4, B=4, S=4, D=8,
                     Dc=4, schedule="cheap-fused",
                     seg_blk=dict(r_blk=8, e_blk=64),
                     serve_devices=None, pipeline=True),
    "serve_s": dict(kind="serve", L=256, E=4096, G=4, B=4, S=4, D=8,
                    Dc=4, schedule="cheap-fused",
                    seg_blk=dict(r_blk=16, e_blk=160),
                    serve_devices=None, pipeline=True),
    "serve_m": dict(kind="serve", L=1024, E=16384, G=4, B=4, S=4, D=8,
                    Dc=4, schedule="cheap-fused",
                    seg_blk=dict(r_blk=32, e_blk=320),
                    serve_devices=None, pipeline=True),
    # shape-descent cells: rungs of the static ladder the staged solver
    # re-packs the alive kernel onto mid-solve (solvers.solve_staged).
    # They extend the serve cells upward so instances too big for serve_m
    # get a descent entry point and become admissible once their kernel
    # fits a serve cell.  G/B/S are floors only — compaction keeps the
    # exact per-PE maxima when they exceed the floor; never mesh dry-run
    # workloads (excluded from ARCH.shapes like the serve cells).
    "descent_l": dict(kind="descent", L=4096, E=65536, G=64, B=64, S=64,
                      D=8, Dc=4, schedule="cheap-fused",
                      seg_blk=dict(r_blk=32, e_blk=512)),
    "descent_xl": dict(kind="descent", L=16384, E=262144, G=128, B=128,
                       S=128, D=8, Dc=4, schedule="cheap-fused",
                       seg_blk=dict(r_blk=32, e_blk=1024)),
}

#: Ladder order (ascending) used by solvers.solve_staged when no explicit
#: ladder is given: serve cells first, then the descent extensions.
MWIS_DESCENT_LADDER = (
    "serve_xs", "serve_s", "serve_m", "descent_l", "descent_xl",
)

#: Static batch-size buckets of the serving layer: a request batch is
#: padded up to the smallest admissible size so (cell × batch) programs
#: are compiled once and reused for the life of the service.
MWIS_SERVE_BATCH_SIZES = (1, 4, 16, 64)


@dataclasses.dataclass
class BuildResult:
    fn: Callable
    abstract_inputs: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    # static metadata for the roofline
    model_flops: float
    note: str = ""
    out_shardings: Any = None   # pinned outputs (train: loss/params/opt)


@dataclasses.dataclass
class ArchDef:
    arch_id: str
    family: str                      # lm | gnn | recsys | mwis
    shapes: Tuple[str, ...]
    build: Callable[[str, Any, Tuple[str, ...]], BuildResult]
    smoke: Callable[[], None]        # runs a reduced config on CPU
    skips: Dict[str, str] = dataclasses.field(default_factory=dict)


def fsdp_axes_for(mesh) -> Tuple[str, ...]:
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))


def sharding_tree(specs, mesh):
    return MC.param_shardings(specs, mesh)


def opt_abstract(params_abs):
    """AdamW state (f32 moments) matching the abstract param tree."""
    f32 = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_abs
    )
    return opt.AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=f32,
        nu=jax.tree.map(lambda s: s, f32),
    )


def opt_shardings(param_sh, mesh):
    return opt.AdamWState(
        step=NamedSharding(mesh, P()),
        mu=param_sh,
        nu=jax.tree.map(lambda s: s, param_sh),
    )


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def ns(mesh, *spec, shape=None):
    p = P(*spec)
    if shape is not None:
        p = MC.sanitize_pspec(tuple(shape), p, mesh)
    return NamedSharding(mesh, p)


def pad_multiple(x: int, m: int = 512) -> int:
    return ((x + m - 1) // m) * m


# --------------------------------------------------------------------- #
# family builders
# --------------------------------------------------------------------- #
def lm_build(cfg, shape_name: str, mesh, fsdp: Tuple[str, ...],
             overrides: Optional[Dict[str, Any]] = None) -> BuildResult:
    from repro.models import transformer as T

    meta = LM_SHAPES[shape_name]
    if overrides:
        cfg = dataclasses.replace(
            cfg, **{k: v for k, v in overrides.items() if hasattr(cfg, k)}
        )
    specs = T.param_specs(cfg, fsdp)
    params_abs = MC.abstract_params(specs)
    params_sh = sharding_tree(specs, mesh)
    B, S = meta["batch"], meta["seq"]
    f = tuple(fsdp)
    ocfg = opt.AdamWConfig()

    if meta["kind"] == "train":
        batch_abs = dict(
            tokens=sds((B, S), jnp.int32), labels=sds((B, S), jnp.int32)
        )
        batch_sh = dict(
            tokens=ns(mesh, f, shape=(B, S)),
            labels=ns(mesh, f, shape=(B, S)),
        )
        opt_abs = opt_abstract(params_abs)
        opt_sh = opt_shardings(params_sh, mesh)

        def train_step(params, ostate, batch):
            loss, grads = jax.value_and_grad(
                lambda p: T.loss_fn(p, batch, cfg)
            )(params)
            params, ostate = opt.adamw_update(grads, ostate, params, ocfg)
            return loss, params, ostate

        flops = 6.0 * cfg.n_active_params() * B * S
        return BuildResult(
            train_step, (params_abs, opt_abs, batch_abs),
            (params_sh, opt_sh, batch_sh), flops,
            out_shardings=(ns(mesh), params_sh, opt_sh),
        )

    if meta["kind"] == "prefill":
        tokens_abs = sds((B, S), jnp.int32)

        def prefill(params, tokens):
            return T.prefill_step(params, tokens, cfg)

        flops = 2.0 * cfg.n_active_params() * B * S
        return BuildResult(
            prefill, (params_abs, tokens_abs),
            (params_sh, ns(mesh, f, shape=(B, S))), flops,
        )

    # decode: one new token against a seq-long KV cache
    shard_seq = B == 1
    (kc_abs, vc_abs), (kc_ps, vc_ps) = T.make_kv_cache_specs(
        cfg, B, S, fsdp=f, shard_seq=shard_seq
    )
    tokens_abs = sds((B, 1), jnp.int32)
    clen_abs = sds((), jnp.int32)

    def decode(params, kc, vc, tokens, cache_len):
        logits, (kc, vc) = T.serve_step(
            params, (kc, vc), tokens, cache_len, cfg
        )
        return logits, kc, vc

    flops = 2.0 * cfg.n_active_params() * B
    return BuildResult(
        decode,
        (params_abs, kc_abs, vc_abs, tokens_abs, clen_abs),
        (params_sh,
         NamedSharding(mesh, MC.sanitize_pspec(kc_abs.shape, kc_ps, mesh)),
         NamedSharding(mesh, MC.sanitize_pspec(vc_abs.shape, vc_ps, mesh)),
         ns(mesh, f, shape=(B, 1)), ns(mesh)),
        flops,
        note="decode against %d-token cache" % S,
    )


def gnn_build(module, cfg, shape_name: str, mesh, fsdp,
              overrides: Optional[Dict[str, Any]] = None,
              *, molecular: bool, flops_fn) -> BuildResult:
    meta = GNN_SHAPES[shape_name]
    if overrides:
        cfg = dataclasses.replace(
            cfg, **{k: v for k, v in overrides.items() if hasattr(cfg, k)}
        )
    # data pipeline pads node/edge counts to shardable multiples
    N = pad_multiple(meta["n_nodes"])
    E2 = pad_multiple(2 * meta["n_edges"])
    d_feat = meta["d_feat"]
    cfg = dataclasses.replace(cfg, d_feat=d_feat)
    specs = module.param_specs(cfg, fsdp)
    params_abs = MC.abstract_params(specs)
    params_sh = sharding_tree(specs, mesh)
    f = tuple(fsdp)
    ocfg = opt.AdamWConfig()

    batch_abs = dict(
        node_feat=sds((N, d_feat), jnp.float32),
        row=sds((E2,), jnp.int32),
        col=sds((E2,), jnp.int32),
        labels=sds((N,), jnp.int32),
        label_mask=sds((N,), jnp.float32),
    )
    ax_all = f + ("model",)
    batch_sh = dict(
        node_feat=ns(mesh, f, None),
        row=ns(mesh, ax_all, shape=(E2,)),
        col=ns(mesh, ax_all, shape=(E2,)),
        labels=ns(mesh, f), label_mask=ns(mesh, f),
    )
    if molecular:
        T_budget = min(8 * E2, 1 << 24)
        batch_abs.update(
            pos=sds((N, 3), jnp.float32),
            batch_id=sds((N,), jnp.int32),
            energy=sds((meta["n_graphs"],), jnp.float32),
            triplets=sds((T_budget, 2), jnp.int32),
            n_graphs=meta["n_graphs"],
        )
        batch_sh.update(
            pos=ns(mesh, f, None), batch_id=ns(mesh, f),
            energy=ns(mesh, None),
            triplets=ns(mesh, ax_all, None, shape=(T_budget, 2)),
            n_graphs=None,
        )

    opt_abs = opt_abstract(params_abs)
    opt_sh = opt_shardings(params_sh, mesh)

    def train_step(params, ostate, batch):
        if molecular:
            batch = dict(batch, n_graphs=meta["n_graphs"])
        loss, grads = jax.value_and_grad(
            lambda p: module.loss_fn(p, batch, cfg)
        )(params)
        params, ostate = opt.adamw_update(grads, ostate, params, ocfg)
        return loss, params, ostate

    if molecular:
        batch_abs.pop("n_graphs")
        batch_sh.pop("n_graphs")
    return BuildResult(
        train_step, (params_abs, opt_abs, batch_abs),
        (params_sh, opt_sh, batch_sh),
        flops_fn(cfg, N, E2),
        out_shardings=(ns(mesh), params_sh, opt_sh),
    )


def dlrm_build(cfg, shape_name: str, mesh, fsdp,
               overrides: Optional[Dict[str, Any]] = None) -> BuildResult:
    from repro.models import dlrm as M

    meta = RECSYS_SHAPES[shape_name]
    specs = M.param_specs(cfg, fsdp)
    params_abs = MC.abstract_params(specs)
    params_sh = sharding_tree(specs, mesh)
    f = tuple(fsdp)
    B = meta["batch"]
    ocfg = opt.AdamWConfig()

    top_dims = (cfg.top_in,) + cfg.top_mlp
    mlp_flops = sum(
        a * b for a, b in zip(cfg.bot_mlp[:-1], cfg.bot_mlp[1:])
    ) + sum(a * b for a, b in zip(top_dims[:-1], top_dims[1:]))
    fwd = 2.0 * B * (
        mlp_flops + (cfg.n_sparse + 1) ** 2 * cfg.embed_dim
        + cfg.n_sparse * cfg.embed_dim
    )

    if meta["kind"] == "train":
        batch_abs = dict(
            dense=sds((B, cfg.n_dense), jnp.float32),
            sparse=sds((B, cfg.n_sparse), jnp.int32),
            labels=sds((B,), jnp.int32),
        )
        batch_sh = dict(
            dense=ns(mesh, f, None), sparse=ns(mesh, f, None),
            labels=ns(mesh, f),
        )
        opt_abs = opt_abstract(params_abs)
        opt_sh = opt_shardings(params_sh, mesh)

        def train_step(params, ostate, batch):
            loss, grads = jax.value_and_grad(
                lambda p: M.loss_fn(p, batch, cfg)
            )(params)
            params, ostate = opt.adamw_update(grads, ostate, params, ocfg)
            return loss, params, ostate

        return BuildResult(
            train_step, (params_abs, opt_abs, batch_abs),
            (params_sh, opt_sh, batch_sh), 3.0 * fwd,
            out_shardings=(ns(mesh), params_sh, opt_sh),
        )

    if meta["kind"] == "serve":
        batch_abs = dict(
            dense=sds((B, cfg.n_dense), jnp.float32),
            sparse=sds((B, cfg.n_sparse), jnp.int32),
        )
        batch_sh = dict(dense=ns(mesh, f, None), sparse=ns(mesh, f, None))

        def serve(params, batch):
            return M.serve_step(params, batch, cfg)

        return BuildResult(
            serve, (params_abs, batch_abs), (params_sh, batch_sh), fwd
        )

    # retrieval: 1 query × n_candidates batched dot
    nc = meta["n_candidates"]
    batch_abs = dict(
        dense=sds((1, cfg.n_dense), jnp.float32),
        candidates=sds((1, nc), jnp.int32),
    )
    batch_sh = dict(dense=ns(mesh), candidates=ns(mesh, None, f))

    def retrieve(params, batch):
        return M.retrieval_step(params, batch, cfg)

    flops = 2.0 * nc * cfg.embed_dim
    return BuildResult(
        retrieve, (params_abs, batch_abs), (params_sh, batch_sh), flops
    )


def mwis_build(shape_name: str, mesh, fsdp,
               overrides: Optional[Dict[str, Any]] = None) -> BuildResult:
    """The paper's workload: DisRedu/RnP over a PE-flattened view of the
    production mesh (pe = pod × data × model)."""
    from repro.core.distributed import DisReduConfig
    from repro.core.partition import PartitionedGraph
    from repro.core import solvers as SOL
    from repro.configs import mwis as _mwis

    meta = MWIS_SHAPES[shape_name]
    p = int(np.prod(mesh.devices.shape))
    L, E, G, B, S, D, Dc = (meta[k] for k in ("L", "E", "G", "B", "S", "D", "Dc"))
    V = L + G + 1

    # abstract PartitionedGraph (shapes only — the dry-run contract)
    pg = PartitionedGraph(
        p=p, n_global=p * L, L=L, G=G, E=E, B=B, S=S, D=D,
        starts=np.linspace(0, p * L, p + 1).astype(np.int64),
        row=None, col=None, w0=None, gid=None, is_local=None, is_ghost=None,
        is_iface=None, deg_local=None, owner_pe=None, iface_slots=None,
        ghost_owner_slot=None, window=None, win_complete=None,
        win_adj_bits=None, edge_common=None, Dc=Dc, send_slot=None,
        recv_ghost=None,
    )
    algo = "reduce" if meta["kind"] == "reduce" else "rnp"
    axis = tuple(mesh.axis_names)
    ov = overrides or {}
    seg_blk = dict(meta.get("seg_blk", {}))
    seg_blk.update(ov.get("seg_blk", {}))
    cfg = DisReduConfig(
        heavy_k=int(ov.get("heavy_k", 8)), mode="async", stale_sweeps=2,
        exchange=ov.get("exchange", "allgather"), max_rounds=64,
        schedule=str(ov.get("schedule", _mwis.rule_schedule(shape_name))),
        backend=str(ov.get("backend", "jnp")),
        use_heavy=bool(ov.get("use_heavy", True)),
        r_blk=seg_blk.get("r_blk"),
    )
    if (overrides or {}).get("probe"):
        # loop-free probe: exactly one rule sweep + one halo exchange —
        # the roofline unit is "per sweep-round" (dynamic trip counts
        # cannot be extrapolated statically)
        run, keys = SOL.sweep_probe_shard_map_fn(pg, cfg, mesh, axis=axis)
    else:
        run, keys = SOL.solver_shard_map_fn(pg, cfg, mesh, algo, axis=axis)

    shapes = dict(
        row=((p, E), jnp.int32), col=((p, E), jnp.int32),
        w0=((p, V), jnp.int32), gid=((p, V), jnp.int32),
        is_local=((p, V), jnp.bool_), is_ghost=((p, V), jnp.bool_),
        is_iface=((p, V), jnp.bool_), owner_pe=((p, V), jnp.int32),
        iface_slots=((p, B), jnp.int32), ghost_owner_slot=((p, G), jnp.int32),
        window=((p, V, D), jnp.int32), win_complete=((p, V), jnp.bool_),
        win_adj_bits=((p, V, D), jnp.int32), edge_common=((p, E, Dc), jnp.int32),
        send_slot=((p, p, S), jnp.int32), recv_ghost=((p, p, S), jnp.int32),
    )
    abstract = {k: sds(*shapes[k]) for k in keys}
    shard = {k: ns(mesh, axis) for k in keys}

    def step(arrays):
        return run(arrays)

    # "useful work": one pass of masked rule aggregates over all edges
    flops = 10.0 * p * E
    return BuildResult(
        step, (abstract,), (shard,), flops,
        note=f"algo={algo} p={p} (PE axis = flattened mesh)",
    )
