"""dlrm-mlperf — MLPerf DLRM (Criteo 1TB): 13 dense + 26 sparse features,
embed_dim 128, bot 13-512-256-128, top 1024-1024-512-256-1, dot interaction.
[arXiv:1906.00091; paper]
"""

from __future__ import annotations

import dataclasses
import functools

from repro.configs import base
from repro.models.dlrm import DLRMConfig

CONFIG = DLRMConfig()

SMOKE = dataclasses.replace(
    CONFIG,
    vocabs=(64, 32, 16, 8, 100, 3, 50, 20, 63, 128, 256, 40, 10, 22, 11,
            15, 4, 9, 14, 200, 250, 300, 58, 12, 10, 36),
    embed_dim=16,
    bot_mlp=(13, 32, 16),
    top_mlp=(64, 32, 1),
)


def smoke():
    from repro.configs.smoke_runners import dlrm_smoke

    dlrm_smoke(SMOKE)


ARCH = base.ArchDef(
    arch_id="dlrm-mlperf",
    family="recsys",
    shapes=tuple(base.RECSYS_SHAPES),
    build=functools.partial(base.dlrm_build, CONFIG),
    smoke=smoke,
)
