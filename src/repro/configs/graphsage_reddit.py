"""graphsage-reddit — 2L d_hidden=128 mean aggregator, fanout 25-10.
[arXiv:1706.02216; paper]
"""

from __future__ import annotations

import dataclasses
import functools

from repro.configs import base
from repro.models.gnn.graphsage import GraphSAGEConfig
from repro.models.gnn import graphsage as module

CONFIG = GraphSAGEConfig(n_layers=2, d_hidden=128, sample_sizes=(25, 10))

SMOKE = dataclasses.replace(CONFIG, d_hidden=16, n_classes=4,
                            sample_sizes=(4, 3))


def _flops(cfg, n, e2):
    per_node = 2 * 2 * cfg.d_feat * cfg.d_hidden
    per_edge = 2 * cfg.d_hidden
    return 3.0 * cfg.n_layers * (n * per_node + e2 * per_edge)


def smoke():
    from repro.configs.smoke_runners import gnn_smoke

    gnn_smoke(module, SMOKE, molecular=False, sampled=True)


ARCH = base.ArchDef(
    arch_id="graphsage-reddit",
    family="gnn",
    shapes=tuple(base.GNN_SHAPES),
    build=functools.partial(
        base.gnn_build, module, CONFIG, molecular=False, flops_fn=_flops
    ),
    smoke=smoke,
)
