"""Reduced-config smoke runners: instantiate a small config of the same
family and run one forward/train step on CPU, asserting output shapes and
finiteness.  Full configs are exercised only via the dry-run."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as MC
from repro.train import optimizer as opt


def _assert_finite(tree, what=""):
    for leaf in jax.tree.leaves(tree):
        arr = np.asarray(leaf)
        if arr.dtype.kind == "f":
            assert np.isfinite(arr).all(), f"non-finite values in {what}"


def lm_smoke(cfg):
    from repro.models import transformer as T

    specs = T.param_specs(cfg)
    params = MC.init_params(specs, jax.random.key(0))
    B, S = 2, 32
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab),
    }
    ostate = opt.adamw_init(params)
    ocfg = opt.AdamWConfig()

    @jax.jit
    def step(params, ostate, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, batch, cfg)
        )(params)
        params, ostate = opt.adamw_update(grads, ostate, params, ocfg)
        return loss, params, ostate

    loss, params2, _ = step(params, ostate, batch)
    assert np.isfinite(float(loss)), "train loss must be finite"
    _assert_finite(params2, f"{cfg.name} params after update")

    # decode step against a KV cache
    (kc_abs, vc_abs), _ = T.make_kv_cache_specs(cfg, B, 64)
    kc = jnp.zeros(kc_abs.shape, kc_abs.dtype)
    vc = jnp.zeros(vc_abs.shape, vc_abs.dtype)

    @jax.jit
    def decode(params, kc, vc, tok, pos):
        return T.serve_step(params, (kc, vc), tok, pos, cfg)

    logits, (kc, vc) = decode(
        params, kc, vc,
        jnp.zeros((B, 1), jnp.int32), jnp.asarray(3, jnp.int32),
    )
    assert logits.shape == (B, cfg.vocab)
    _assert_finite(logits, f"{cfg.name} decode logits")


def gnn_smoke(module, cfg, *, molecular: bool, sampled: bool = False):
    from repro.graphs import generators as gen
    from repro.graphs.sampler import sample_fanout, build_triplets

    rng = np.random.default_rng(0)
    g = gen.rgg2d(120, avg_deg=6, seed=0)
    if sampled:
        sub = sample_fanout(
            g, np.arange(8), cfg.sample_sizes, rng=rng,
            pad_nodes=160, pad_edges=400,
        )
        row, col = sub.row, sub.col
        n = sub.n_sub
    else:
        src = g.edge_sources()
        row = src.astype(np.int32)
        col = g.indices.astype(np.int32)
        n = g.n
    d_feat = getattr(cfg, "d_feat", 16)
    batch = dict(
        node_feat=jnp.asarray(rng.normal(size=(n, d_feat)), jnp.float32),
        row=jnp.asarray(row), col=jnp.asarray(col),
        labels=jnp.asarray(rng.integers(0, 4, size=n), jnp.int32),
        label_mask=jnp.ones((n,), jnp.float32),
    )
    if molecular:
        tri = build_triplets(np.asarray(row), np.asarray(col), n,
                             budget=4 * row.shape[0])
        batch.update(
            pos=jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
            batch_id=jnp.zeros((n,), jnp.int32),
            energy=jnp.zeros((1,), jnp.float32),
            triplets=jnp.asarray(tri),
        )
    specs = module.param_specs(cfg)
    params = MC.init_params(specs, jax.random.key(0))
    ostate = opt.adamw_init(params)
    ocfg = opt.AdamWConfig()

    @jax.jit
    def step(params, ostate, batch):
        if molecular:
            batch = dict(batch, n_graphs=1)  # static
        loss, grads = jax.value_and_grad(
            lambda p: module.loss_fn(p, batch, cfg)
        )(params)
        params, ostate = opt.adamw_update(grads, ostate, params, ocfg)
        return loss, params, ostate

    loss, params2, _ = step(params, ostate, batch)
    assert np.isfinite(float(loss)), "gnn loss must be finite"
    _assert_finite(params2, "gnn params after update")


def dlrm_smoke(cfg):
    from repro.models import dlrm as M

    specs = M.param_specs(cfg)
    params = MC.init_params(specs, jax.random.key(0))
    rng = np.random.default_rng(0)
    B = 16
    batch = dict(
        dense=jnp.asarray(rng.normal(size=(B, cfg.n_dense)), jnp.float32),
        sparse=jnp.asarray(
            rng.integers(0, 3, size=(B, cfg.n_sparse)), jnp.int32
        ),
        labels=jnp.asarray(rng.integers(0, 2, size=B), jnp.int32),
    )
    ostate = opt.adamw_init(params)
    ocfg = opt.AdamWConfig()

    @jax.jit
    def step(params, ostate, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, batch, cfg)
        )(params)
        params, ostate = opt.adamw_update(grads, ostate, params, ocfg)
        return loss, params, ostate

    loss, params2, _ = step(params, ostate, batch)
    assert np.isfinite(float(loss))
    _assert_finite(params2, "dlrm params")

    probs = jax.jit(lambda p, b: M.serve_step(p, b, cfg))(
        params, {k: batch[k] for k in ("dense", "sparse")}
    )
    assert probs.shape == (B,)
    # retrieval scoring
    rb = dict(
        dense=batch["dense"][:1],
        candidates=jnp.asarray(
            rng.integers(0, cfg.vocabs[0], size=(1, 64)), jnp.int32
        ),
    )
    scores = jax.jit(lambda p, b: M.retrieval_step(p, b, cfg))(params, rb)
    assert scores.shape == (64,)


def mwis_smoke():
    """Reduced end-to-end MWIS: partition → DisReduA → RnP → verify."""
    from repro.core import partition as part, solvers as S
    from repro.core.distributed import DisReduConfig
    from repro.graphs import generators as gen

    g = gen.rgg2d(200, avg_deg=6, seed=0)
    pg = part.partition_graph(g, 4, window_cap=8)
    members, _ = S.solve(pg, "rnp", DisReduConfig(heavy_k=6, mode="async"))
    assert g.is_independent_set(members)
    assert g.set_weight(members) > 0
