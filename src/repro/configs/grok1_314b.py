"""grok-1-314b — 64L d_model=6144 48H (GQA kv=8, d_head=128) d_ff=32768,
MoE 8 experts top-2, vocab 131072.  [hf:xai-org/grok-1; unverified]
"""

from __future__ import annotations

import dataclasses
import functools

from repro.configs import base
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="grok-1-314b",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=32768, vocab=131072, moe_experts=8, moe_top_k=2,
    attn_chunk=512,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=96, vocab=128, moe_experts=4, moe_top_k=2, attn_chunk=32,
    loss_chunks=2,
)


def smoke():
    from repro.configs.smoke_runners import lm_smoke

    lm_smoke(SMOKE)


ARCH = base.ArchDef(
    arch_id="grok-1-314b",
    family="lm",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    build=functools.partial(base.lm_build, CONFIG),
    smoke=smoke,
    skips={"long_500k": "pure full-attention arch (assignment rule)"},
)
