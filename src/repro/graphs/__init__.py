from repro.graphs import generators  # noqa: F401
