"""Fanout neighbor sampler (GraphSAGE-style) — the real sampler required by
the ``minibatch_lg`` shape.

Given a CSR graph, seed nodes and fanouts (f_1, ..., f_k), builds a padded
sampled subgraph with static shapes:

  * nodes: seeds first, then layer-by-layer sampled frontiers (deduped),
  * edges: (src_local → dst_local) for every sampled (neighbor → target),
  * padding uses the sentinel index n_sub so model code can mask uniformly.

The sampler runs host-side (numpy RNG) — it is the data-pipeline stage of
the framework; its output feeds the jitted train step.  On the *reduced*
graph (after `core.distributed` kernelization) the same sampler applies —
that is the paper-technique × GNN-substrate integration point.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass(frozen=True)
class SampledSubgraph:
    node_ids: np.ndarray   # [n_sub] global ids (pad = -1)
    row: np.ndarray        # [e_sub] local src (pad = n_sub)
    col: np.ndarray        # [e_sub] local dst (pad = n_sub)
    n_valid: int
    n_seeds: int

    @property
    def n_sub(self) -> int:
        return int(self.node_ids.shape[0])


def sample_fanout(
    g: Graph,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    *,
    rng: np.random.Generator,
    pad_nodes: int | None = None,
    pad_edges: int | None = None,
) -> SampledSubgraph:
    """k-hop fanout sampling with dedup; returns a padded subgraph."""
    seeds = np.asarray(seeds, dtype=np.int64)
    order: Dict[int, int] = {int(s): i for i, s in enumerate(seeds)}
    nodes = list(seeds)
    edges_src: list = []
    edges_dst: list = []
    frontier = seeds
    for f in fanouts:
        nxt = []
        for v in frontier:
            nbrs = g.neighbors(int(v))
            if nbrs.shape[0] == 0:
                continue
            take = nbrs if nbrs.shape[0] <= f else rng.choice(
                nbrs, size=f, replace=False
            )
            for u in take.tolist():
                if u not in order:
                    order[u] = len(nodes)
                    nodes.append(u)
                    nxt.append(u)
                edges_src.append(order[u])
                edges_dst.append(order[int(v)])
        frontier = np.asarray(nxt, dtype=np.int64)
    n_valid = len(nodes)
    n_e = len(edges_src)
    n_sub = pad_nodes or n_valid
    e_sub = pad_edges or n_e
    assert n_valid <= n_sub and n_e <= e_sub, "pad sizes too small"
    node_ids = np.full(n_sub, -1, dtype=np.int64)
    node_ids[:n_valid] = nodes
    row = np.full(e_sub, n_sub, dtype=np.int32)
    col = np.full(e_sub, n_sub, dtype=np.int32)
    row[:n_e] = edges_src
    col[:n_e] = edges_dst
    return SampledSubgraph(
        node_ids=node_ids, row=row, col=col,
        n_valid=n_valid, n_seeds=int(seeds.shape[0]),
    )


def build_triplets(
    row: np.ndarray, col: np.ndarray, n: int, *,
    budget: int, cap_per_edge: int = 8,
) -> np.ndarray:
    """Capped triplet list (in-edge k→j, out-edge j→i) for angular GNNs.

    For each out-edge (j→i), pair with up to `cap_per_edge` in-edges (k→j),
    k ≠ i; truncated to `budget` rows, padded with e_sub sentinels.
    """
    e_sub = row.shape[0]
    by_dst: Dict[int, list] = {}
    for e in range(e_sub):
        if row[e] < n:
            by_dst.setdefault(int(col[e]), []).append(e)
    out = []
    for e_out in range(e_sub):
        j = int(row[e_out])
        if j >= n:
            continue
        i = int(col[e_out])
        cnt = 0
        for e_in in by_dst.get(j, []):
            if int(row[e_in]) == i:
                continue
            out.append((e_in, e_out))
            cnt += 1
            if cnt >= cap_per_edge:
                break
        if len(out) >= budget:
            break
    tri = np.full((budget, 2), e_sub, dtype=np.int32)
    k = min(len(out), budget)
    if k:
        tri[:k] = np.asarray(out[:k], dtype=np.int32)
    return tri
