"""Graph instance generators — KaGen stand-ins for the paper's weak-scaling set.

The paper's weak-scaling experiments (§7) use three families generated with
KaGen [17]:

  * GNM — Erdős–Rényi G(n, m): barely reducible (Table C.4: |V'|/|V| = 0.98),
  * RGG — 2D random geometric: reduces to ~34 %,
  * RHG — random hyperbolic, power-law γ = 2.8: reduces to ≈ 0.01 %.

These reproduce the *qualitative reduction-impact spread* that drives the
paper's evaluation.  All generators are deterministic in `seed` and return
:class:`repro.core.graph.Graph` with uniform random integer weights in
[1, 200] (the paper's weight model, Table C.1 'uf [1, 200]').
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import Graph, from_edge_list

WEIGHT_LO, WEIGHT_HI = 1, 200


def _weights(n: int, rng: np.random.Generator) -> np.ndarray:
    return rng.integers(WEIGHT_LO, WEIGHT_HI + 1, size=n, dtype=np.int32)


def gnm(n: int, m: int, seed: int = 0) -> Graph:
    """Erdős–Rényi G(n, m) — uniform random edge set without replacement."""
    rng = np.random.default_rng(seed)
    # Rejection-free sampling of undirected pairs: sample with margin, dedup.
    want = m
    pairs = np.zeros((0, 2), dtype=np.int64)
    attempts = 0
    while pairs.shape[0] < want and attempts < 64:
        k = int((want - pairs.shape[0]) * 1.4) + 16
        u = rng.integers(0, n, size=k, dtype=np.int64)
        v = rng.integers(0, n, size=k, dtype=np.int64)
        keep = u != v
        lo = np.minimum(u[keep], v[keep])
        hi = np.maximum(u[keep], v[keep])
        cand = np.stack([lo, hi], axis=1)
        pairs = np.unique(np.concatenate([pairs, cand], axis=0), axis=0)
        attempts += 1
    pairs = pairs[:want]
    return from_edge_list(n, pairs, _weights(n, rng))


def rgg2d(n: int, radius: float | None = None, *, avg_deg: float = 8.0,
          seed: int = 0) -> Graph:
    """2D random geometric graph on the unit square (grid-bucketed O(n))."""
    rng = np.random.default_rng(seed)
    if radius is None:
        # E[deg] = n * pi * r^2  =>  r = sqrt(avg_deg / (pi n))
        radius = float(np.sqrt(avg_deg / (np.pi * n)))
    pts = rng.random((n, 2))
    # Spatially coherent vertex ids (sort by grid cell), matching KaGen's
    # per-PE generation: contiguous 1D blocks then correspond to spatial
    # regions, as in the paper's distributed inputs.
    _nc = max(1, int(1.0 / max(radius, 1e-9)))
    _cx = np.minimum((pts[:, 0] / max(radius, 1e-9)).astype(np.int64), _nc - 1)
    _cy = np.minimum((pts[:, 1] / max(radius, 1e-9)).astype(np.int64), _nc - 1)
    pts = pts[np.argsort(_cx * _nc + _cy, kind="stable")]
    cell = max(radius, 1e-9)
    ncell = max(1, int(1.0 / cell))
    cx = np.minimum((pts[:, 0] / cell).astype(np.int64), ncell - 1)
    cy = np.minimum((pts[:, 1] / cell).astype(np.int64), ncell - 1)
    cid = cx * ncell + cy
    order = np.argsort(cid, kind="stable")
    sorted_cid = cid[order]
    starts = np.searchsorted(sorted_cid, np.arange(ncell * ncell))
    ends = np.searchsorted(sorted_cid, np.arange(ncell * ncell), side="right")

    src_list, dst_list = [], []
    r2 = radius * radius
    for gx in range(ncell):
        for gy in range(ncell):
            mine = order[starts[gx * ncell + gy]: ends[gx * ncell + gy]]
            if mine.size == 0:
                continue
            # neighbors: same + 4 forward cells (avoid double counting)
            for dx, dy in ((0, 0), (1, 0), (0, 1), (1, 1), (1, -1)):
                nx, ny = gx + dx, gy + dy
                if not (0 <= nx < ncell and 0 <= ny < ncell):
                    continue
                other = order[starts[nx * ncell + ny]: ends[nx * ncell + ny]]
                if other.size == 0:
                    continue
                d = pts[mine, None, :] - pts[None, other, :]
                close = (d * d).sum(-1) <= r2
                ii, jj = np.nonzero(close)
                uu, vv = mine[ii], other[jj]
                if dx == 0 and dy == 0:
                    keep = uu < vv
                    uu, vv = uu[keep], vv[keep]
                src_list.append(uu)
                dst_list.append(vv)
    if src_list:
        src = np.concatenate(src_list)
        dst = np.concatenate(dst_list)
        pairs = np.stack([src, dst], axis=1)
    else:
        pairs = np.zeros((0, 2), dtype=np.int64)
    return from_edge_list(n, pairs, _weights(n, rng))


def rhg(n: int, avg_deg: float = 8.0, gamma: float = 2.8,
        seed: int = 0) -> Graph:
    """True random hyperbolic graph (threshold model, exact O(n²) pairing —
    test/bench scale).  Points in the hyperbolic disk (radial density
    ~ e^{αr} with α = (γ−1)/2, uniform angle); vertices adjacent iff their
    hyperbolic distance is below a threshold picked to hit `avg_deg`
    exactly.  This reproduces the power-law degrees AND the hierarchical
    clustering that make the paper's RHG instances collapse under
    reductions (Table C.4).  Ids sorted by angle (KaGen-style locality).
    """
    rng = np.random.default_rng(seed)
    alpha = (gamma - 1.0) / 2.0
    R0 = 2.0 * np.log(n)
    u = rng.random(n)
    r = np.arccosh(1.0 + u * (np.cosh(alpha * R0) - 1.0)) / alpha
    theta = np.sort(rng.random(n) * 2 * np.pi)  # angular-sorted ids
    m_target = int(avg_deg * n / 2)

    # pairwise hyperbolic distances, chunked; threshold at the m-th smallest
    ch = np.cosh(r)
    sh = np.sinh(r)
    dists = []
    pairs_i = []
    pairs_j = []
    step = max(1, 2_000_000 // max(n, 1))
    for i0 in range(0, n, step):
        i1 = min(n, i0 + step)
        ii = np.arange(i0, i1)
        cosd = (
            ch[ii, None] * ch[None, :]
            - sh[ii, None] * sh[None, :] * np.cos(
                theta[ii, None] - theta[None, :]
            )
        )
        d = np.arccosh(np.maximum(cosd, 1.0))
        jj = np.arange(n)
        mask = jj[None, :] > ii[:, None]
        sel_i, sel_j = np.nonzero(mask)
        dd = d[sel_i, sel_j]
        keep = dd <= R0  # pre-filter to keep memory bounded
        dists.append(dd[keep])
        pairs_i.append(ii[sel_i][keep])
        pairs_j.append(jj[sel_j][keep])
    dd = np.concatenate(dists)
    pi = np.concatenate(pairs_i)
    pj = np.concatenate(pairs_j)
    if dd.shape[0] > m_target:
        thr = np.partition(dd, m_target - 1)[m_target - 1]
        keep = dd <= thr
        pi, pj = pi[keep], pj[keep]
    pairs = np.stack([pi, pj], axis=1)
    return from_edge_list(n, pairs, _weights(n, rng))


def rhg_like(n: int, avg_deg: float = 8.0, gamma: float = 2.8,
             seed: int = 0) -> Graph:
    """Power-law graph (Chung–Lu) standing in for KaGen's random hyperbolic
    generator: degree distribution ~ k^-gamma, strong local clustering is NOT
    modelled, but the reduction-relevant property — a heavy-tailed degree
    sequence with a vast low-degree periphery — is.
    """
    rng = np.random.default_rng(seed)
    # Chung-Lu with a power-law degree sequence P(k) ~ k^-gamma, k >= 1:
    # inverse-CDF sampling gives the RHG-like shape — a vast degree-1/2
    # periphery plus heavy hubs — which is what drives the near-total
    # reducibility of RHG instances in the paper (Table C.4).
    u = rng.random(n)
    wts = (1.0 - u) ** (-1.0 / (gamma - 1.0))      # Pareto(k_min=1)
    wts = np.minimum(wts, np.sqrt(n))              # hub cutoff
    wts *= (avg_deg * n) / wts.sum()
    wts = np.sort(wts)[::-1]                       # hubs first (locality)
    total = wts.sum()
    m = int(avg_deg * n / 2)
    p = wts / total
    u = rng.choice(n, size=2 * m, p=p)
    v = rng.choice(n, size=2 * m, p=p)
    keep = u != v
    pairs = np.stack([u[keep], v[keep]], axis=1)[:m]
    g = from_edge_list(n, pairs, _weights(n, rng))
    return g


def random_graph(n: int, p_edge: float, seed: int = 0) -> Graph:
    """Dense-ish uniform random graph (tests / brute-force oracles)."""
    rng = np.random.default_rng(seed)
    iu = np.triu_indices(n, k=1)
    mask = rng.random(iu[0].shape[0]) < p_edge
    pairs = np.stack([iu[0][mask], iu[1][mask]], axis=1)
    return from_edge_list(n, pairs, _weights(n, rng))


def path_graph(n: int, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    pairs = np.stack([np.arange(n - 1), np.arange(1, n)], axis=1)
    return from_edge_list(n, pairs, _weights(n, rng))


def star_graph(n_leaves: int, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    pairs = np.stack(
        [np.zeros(n_leaves, dtype=np.int64), np.arange(1, n_leaves + 1)], axis=1
    )
    return from_edge_list(n_leaves + 1, pairs, _weights(n_leaves + 1, rng))


FAMILIES = {
    "gnm": lambda n, seed=0: gnm(n, 4 * n, seed=seed),
    "rgg": lambda n, seed=0: rgg2d(n, avg_deg=8.0, seed=seed),
    "rhg": lambda n, seed=0: rhg(n, avg_deg=8.0, seed=seed),
}
