"""Locality-improving vertex orders — the paper's partitioning enhancement.

The paper (§7.1, Table C.3) shows that partitioning the input with
dKaMinPar before reducing improves reduction impact (|V'|/|V| 0.38 → 0.25
median) at ~10× running-time cost.  Contiguous 1D blocks over a
locality-aware vertex ORDER approximate that effect at near-zero cost: a
BFS order places neighbors in the same block far more often than the
natural order of, e.g., KaGen-style generators.
"""

from __future__ import annotations

from collections import deque

import numpy as np

from repro.core.graph import Graph, relabel


def bfs_order(g: Graph, start: int = 0) -> np.ndarray:
    """perm[v] = new id of old vertex v, by BFS layers (components chained)."""
    n = g.n
    perm = -np.ones(n, dtype=np.int64)
    nxt = 0
    seen = np.zeros(n, dtype=bool)
    for root in range(n):
        if seen[root]:
            continue
        q = deque([root])
        seen[root] = True
        while q:
            v = q.popleft()
            perm[v] = nxt
            nxt += 1
            for u in g.neighbors(v).tolist():
                if not seen[u]:
                    seen[u] = True
                    q.append(u)
    return perm


def relabel_bfs(g: Graph) -> Graph:
    return relabel(g, bfs_order(g))


def cut_edges_fraction(g: Graph, p: int) -> float:
    """Fraction of edges crossing contiguous p-block boundaries."""
    starts = np.linspace(0, g.n, p + 1).astype(np.int64)
    block = np.searchsorted(starts, np.arange(g.n), side="right") - 1
    src = g.edge_sources()
    return float((block[src] != block[g.indices]).mean())
