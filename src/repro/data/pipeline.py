"""Deterministic synthetic data pipelines, indexable by step.

Restart-safety contract (used by `distributed.fault.TrainSupervisor`): a
batch is a pure function of (seed, step), so resuming at step k replays
nothing and skips nothing — no data-loader state needs checkpointing.
Sharded loading: each host materializes only its slice of the global batch
(`host_slice`), the standard multi-host input pattern.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMBatchSpec:
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0


def lm_batch(spec: LMBatchSpec, step: int,
             host_slice: Optional[Tuple[int, int]] = None) -> Dict[str, np.ndarray]:
    lo, hi = host_slice or (0, spec.global_batch)
    rng = np.random.default_rng(
        np.random.SeedSequence([spec.seed, step, lo])
    )
    b = hi - lo
    # Zipf-ish marginal over the vocab + shifted-label LM convention
    tokens = (rng.pareto(1.2, size=(b, spec.seq_len + 1)) * 17).astype(np.int64)
    tokens = np.minimum(tokens, spec.vocab - 1).astype(np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


@dataclasses.dataclass(frozen=True)
class DLRMBatchSpec:
    global_batch: int
    n_dense: int
    n_sparse: int
    vocabs: Tuple[int, ...]
    seed: int = 0


def dlrm_batch(spec: DLRMBatchSpec, step: int,
               host_slice: Optional[Tuple[int, int]] = None) -> Dict[str, np.ndarray]:
    lo, hi = host_slice or (0, spec.global_batch)
    rng = np.random.default_rng(np.random.SeedSequence([spec.seed, step, lo]))
    b = hi - lo
    dense = rng.normal(size=(b, spec.n_dense)).astype(np.float32)
    sparse = np.stack(
        [rng.integers(0, v, size=b) for v in spec.vocabs[: spec.n_sparse]],
        axis=1,
    ).astype(np.int32)
    labels = rng.integers(0, 2, size=b).astype(np.int32)
    return {"dense": dense, "sparse": sparse, "labels": labels}
