"""Render EXPERIMENTS.md tables from dry-run artifacts."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List


def load(art_dir: str, tag: str = "") -> List[Dict]:
    out = []
    sfx = f"_{tag}.json" if tag else ".json"
    for fn in sorted(glob.glob(os.path.join(art_dir, f"*{sfx}"))):
        base = os.path.basename(fn)[: -len(".json")]
        parts = base.split("__")
        if tag and not base.endswith(f"_{tag}"):
            continue
        if not tag and len(parts) == 3 and "_" in parts[2] and \
                parts[2] not in ("single", "multi"):
            continue
        with open(fn) as f:
            out.append(json.load(f))
    return out


def fmt_si(x: float) -> str:
    for div, sfx in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "K")):
        if abs(x) >= div:
            return f"{x / div:.2f}{sfx}"
    return f"{x:.1f}"


def roofline_table(records: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | t_comp(s) | t_mem(s) | t_coll(s) | "
           "bound | useful | roofline_frac | temp(GB) |")
    sep = "|" + "---|" * 10
    rows = [hdr, sep]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if not r.get("ok"):
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAILED |"
            )
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['t_compute_s']:.3e} | {rf['t_memory_s']:.3e} "
            f"| {rf['t_collective_s']:.3e} | {rf['bottleneck'][:4]} "
            f"| {rf['useful_fraction']:.3f} "
            f"| {rf['roofline_fraction']:.4f} "
            f"| {r['memory']['temp_bytes'] / 1e9:.2f} |"
        )
    return "\n".join(rows)


def dryrun_table(records: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | chips | compile(s) | args(GB) | "
           "temp(GB) | flops/dev | bytes/dev | coll bytes/dev |")
    sep = "|" + "---|" * 10
    rows = [hdr, sep]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAILED |")
            continue
        m, c = r["memory"], r["cost"]
        coll = sum(r["collectives"].values())
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['n_chips']} "
            f"| {r['compile_s']:.1f} | {m['argument_bytes'] / 1e9:.2f} "
            f"| {m['temp_bytes'] / 1e9:.2f} | {fmt_si(c['flops'])} "
            f"| {fmt_si(c['bytes_accessed'])} | {fmt_si(coll)} |"
        )
    return "\n".join(rows)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--kind", default="roofline",
                    choices=("roofline", "dryrun"))
    args = ap.parse_args()
    recs = load(args.dir, args.tag)
    if args.kind == "roofline":
        print(roofline_table(recs))
    else:
        print(dryrun_table(recs))


if __name__ == "__main__":
    main()
