"""Exact roofline terms via probe extrapolation.

`cost_analysis()` of a compiled module counts while-loop bodies ONCE, so a
scanned 64-layer model reports ~1 layer of FLOPs.  The probe compiles
(launch/dryrun.py --probe) fully unroll every scan at two reduced layer
counts L ∈ {2, 4}; per-layer cost is constant, so

    cost(L) = intercept + slope · L            (exact, not a model fit)

and cost(L_full) extrapolates exactly.  Loop-free families (recsys,
graphsage) take the single probe verbatim; MWIS probes are a loop-free
single sweep-round (the reported unit — dynamic trip counts are a runtime
quantity).

Writes `<arch>__<shape>__<mesh>_final.json` with corrected terms; the
baseline artifact keeps memory_analysis (the fits-per-device proof).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, Optional

from repro.analysis import roofline as rl

FULL_LAYERS = {
    "qwen3-moe-235b-a22b": 94, "grok-1-314b": 64, "mistral-nemo-12b": 40,
    "qwen3-32b": 64, "gemma3-1b": 26,
    "equiformer-v2": 12, "dimenet": 6, "gatedgcn": 16,
}


def _load(fn: str) -> Optional[Dict]:
    if not os.path.exists(fn):
        return None
    with open(fn) as f:
        return json.load(f)


def _terms(rec: Dict) -> Dict[str, float]:
    return dict(
        flops=rec["cost"]["flops"],
        mem=rec["cost"]["bytes_accessed"],
        coll=float(sum(rec["collectives"].values())),
    )


def finalize_cell(art_dir: str, arch: str, shape: str, mesh: str) -> Optional[Dict]:
    base = _load(os.path.join(art_dir, f"{arch}__{shape}__{mesh}.json"))
    if not base or not base.get("ok"):
        return None
    p2 = _load(os.path.join(art_dir, f"{arch}__{shape}__{mesh}_probep2.json"))
    p4 = _load(os.path.join(art_dir, f"{arch}__{shape}__{mesh}_probep4.json"))
    p1 = _load(os.path.join(art_dir, f"{arch}__{shape}__{mesh}_probep1.json"))
    sweep = _load(
        os.path.join(art_dir, f"{arch}__{shape}__{mesh}_probesweep.json")
    )
    note = ""
    if p2 and p4 and p2.get("ok") and p4.get("ok"):
        t2, t4 = _terms(p2), _terms(p4)
        L = FULL_LAYERS[arch]
        ext = {
            k: t2[k] + (t4[k] - t2[k]) / 2.0 * (L - 2) for k in t2
        }
        note = f"extrapolated from unrolled probes L=2,4 -> L={L}"
    elif p1 and p1.get("ok"):
        ext = _terms(p1)
        note = "loop-free arch: probe cost is exact"
    elif sweep and sweep.get("ok"):
        ext = _terms(sweep)
        note = "MWIS: per sweep-round unit (dynamic trip counts)"
    else:
        return None
    roof = rl.Roofline(
        flops=ext["flops"], mem_bytes=ext["mem"], coll_bytes=ext["coll"],
        model_flops=base["roofline"]["model_flops_per_device"],
    )
    out = dict(base)
    out["roofline"] = roof.report()
    out["cost"] = dict(flops=ext["flops"], bytes_accessed=ext["mem"])
    out["collectives"] = {"extrapolated_total": int(ext["coll"])}
    out["note"] = (out.get("note", "") + "; " + note).strip("; ")
    fn = os.path.join(art_dir, f"{arch}__{shape}__{mesh}_final.json")
    with open(fn, "w") as f:
        json.dump(out, f, indent=1)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    done, missing = 0, []
    for fn in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        b = os.path.basename(fn)[:-5]
        parts = b.split("__")
        if len(parts) != 3 or "_probe" in parts[2] or "_final" in parts[2]:
            continue
        arch, shape, mesh = parts
        if finalize_cell(args.dir, arch, shape, mesh):
            done += 1
        else:
            missing.append((arch, shape, mesh))
    print(f"finalized {done} cells; missing probes for {len(missing)}")
    for m in missing[:20]:
        print("  missing:", *m)


if __name__ == "__main__":
    main()
