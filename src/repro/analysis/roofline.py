"""Three-term roofline from the compiled dry-run artifact (TPU v5e target).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(`cost_analysis()` of a compiled SPMD executable is already per-device, so
dividing by per-chip peaks is the per-formula "HLO_X / (chips × peak)".)

Plus MODEL_FLOPS / HLO_FLOPs — the useful-compute fraction that catches
remat/redundancy waste.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12      # bf16 FLOP/s
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s per ICI link


@dataclasses.dataclass
class Roofline:
    flops: float
    mem_bytes: float
    coll_bytes: float
    model_flops: float

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.mem_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline-model step time (max of the three overlapping terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (remat/redundancy waste detector)."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the modelled step
        time: (MODEL_FLOPS/chips)/peak ÷ t_bound — the §Perf score proxy."""
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS) / self.t_bound

    def report(self) -> Dict[str, float]:
        return dict(
            flops_per_device=self.flops,
            hbm_bytes_per_device=self.mem_bytes,
            collective_bytes_per_device=self.coll_bytes,
            t_compute_s=self.t_compute,
            t_memory_s=self.t_memory,
            t_collective_s=self.t_collective,
            bottleneck=self.bottleneck,
            model_flops_per_device=self.model_flops,
            useful_fraction=self.useful_fraction,
            roofline_fraction=self.roofline_fraction,
        )


def from_cell(cost: Dict, coll: Dict[str, int], model_flops_total: float,
              n_chips: int) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    mem = float(cost.get("bytes accessed", 0.0))
    cb = float(sum(coll.values()))
    return Roofline(
        flops=flops, mem_bytes=mem, coll_bytes=cb,
        model_flops=model_flops_total / max(n_chips, 1),
    )
