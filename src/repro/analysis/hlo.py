"""HLO text analysis: collective-byte accounting for the roofline.

``compiled.cost_analysis()`` reports FLOPs and memory bytes but not
collective traffic — we parse the (post-SPMD, per-device) HLO text and sum
operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.

Counting convention: bytes = size of the op's *output* operand (for
all-gather that is the gathered result ≈ wire bytes received; for
all-reduce the reduced tensor ≈ bytes sent+received/2; exact link-level
accounting is topology-dependent — this uniform convention is applied to
baseline and optimized variants alike, which is what the §Perf deltas
need).  Ops inside while/fusion bodies are counted once per appearance
(static trip counts are not recovered from HLO text) — noted in
EXPERIMENTS.md where it matters.
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|[\w\[\],{}\s]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE,
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind over the HLO module text."""
    out: Dict[str, int] = {}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        if kind.endswith("-done"):
            continue
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0) + b
    return out


def collective_ops(hlo_text: str) -> List[Tuple[str, int]]:
    """(kind, bytes) per collective op, in program order."""
    ops = []
    for m in _OP_RE.finditer(hlo_text):
        ops.append((m.group(2), _shape_bytes(m.group(1))))
    return ops


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
