"""Shared model substrate: param specs, norms, RoPE, chunked attention, loss.

Models are pure-JAX (no flax): parameters are nested dicts of arrays, each
described by a :class:`ParamSpec` carrying shape, dtype, a PartitionSpec for
the production mesh, and an initializer.  ``abstract_params`` produces the
ShapeDtypeStruct pytree the multi-pod dry-run lowers against (no allocation);
``init_params`` materializes small configs for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    pspec: P = P()
    init: str = "normal"   # normal | zeros | ones
    scale: float = 0.02


ParamTree = Dict[str, Any]  # nested dict of ParamSpec / arrays


def abstract_params(specs: ParamTree) -> ParamTree:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def sanitize_pspec(shape: Tuple[int, ...], pspec: P, mesh) -> P:
    """Drop mesh axes from dims they don't divide (jit in_shardings require
    exact divisibility, unlike with_sharding_constraint)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = list(pspec) + [None] * (len(shape) - len(pspec))
    out = []
    for dim, ent in zip(shape, entries[: len(shape)]):
        if ent is None:
            out.append(None)
            continue
        axes = ent if isinstance(ent, tuple) else (ent,)
        axes = tuple(a for a in axes if a in sizes)
        # greedily keep the prefix of axes whose product divides the dim
        kept = []
        prod = 1
        for a in axes:
            if dim % (prod * sizes[a]) == 0:
                kept.append(a)
                prod *= sizes[a]
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


def param_shardings(specs: ParamTree, mesh) -> ParamTree:
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda s: NamedSharding(mesh, sanitize_pspec(s.shape, s.pspec, mesh)),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def init_params(specs: ParamTree, key: jax.Array) -> ParamTree:
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))

    def one(s: ParamSpec, k):
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        return (jax.random.normal(k, s.shape, jnp.float32) * s.scale).astype(
            s.dtype
        )

    return jax.tree.unflatten(treedef, [one(s, k) for s, k in zip(leaves, keys)])


def count_params(specs: ParamTree) -> int:
    leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    return sum(int(math.prod(s.shape)) for s in leaves)


# --------------------------------------------------------------------- #
# activation-sharding hints (GSPMD constraints; no-op without a mesh)
# --------------------------------------------------------------------- #
_HINT_MESH = None


def set_hint_mesh(mesh) -> None:
    """Install the mesh used by shard_hint (dry-run / production jit)."""
    global _HINT_MESH
    _HINT_MESH = mesh


def hint_axis_size(name: str):
    """Size of a mesh axis under the installed hint mesh (None if no mesh)."""
    if _HINT_MESH is None:
        return None
    return dict(
        zip(_HINT_MESH.axis_names, _HINT_MESH.devices.shape)
    ).get(name)


def shard_hint(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint with 'fsdp' placeholder resolution and
    divisibility sanitation; identity when no mesh is installed (CPU smoke
    tests)."""
    if _HINT_MESH is None:
        return x
    from jax.sharding import NamedSharding

    names = _HINT_MESH.axis_names
    fsdp = tuple(a for a in names if a in ("pod", "data"))
    resolved = []
    for ent in spec:
        if ent == "fsdp":
            resolved.append(fsdp)
        elif ent == "all":
            resolved.append(tuple(names))
        else:
            resolved.append(ent)
    p = sanitize_pspec(x.shape, P(*resolved), _HINT_MESH)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_HINT_MESH, p))


# --------------------------------------------------------------------- #
# numerics
# --------------------------------------------------------------------- #
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: [..., T, H, Dh]; positions: [..., T]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("btd,df->btf", x, w_gate.astype(x.dtype))
    u = jnp.einsum("btd,df->btf", x, w_up.astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("btf,fd->btd", h, w_down.astype(x.dtype))


# --------------------------------------------------------------------- #
# attention — chunked online-softmax (flash-style, pure jnp) + decode
# --------------------------------------------------------------------- #
NEG_INF = -1e30


def chunked_attention(
    q: jax.Array,            # [B, T, H, Dh]
    k: jax.Array,            # [B, S, Hkv, Dh]
    v: jax.Array,            # [B, S, Hkv, Dh]
    *,
    causal: bool = True,
    window: Optional[jax.Array] = None,  # sliding window (tokens), None = full
    q_offset: int = 0,       # absolute position of q[0]
    chunk: int = 1024,
) -> jax.Array:
    """Flash-style attention, doubly tiled: outer scan over q blocks, inner
    scan over KV blocks with running (max, denom).  The live tile is
    [B, qc, H, kc] — never the [T, S] score matrix.  GQA via head-group
    broadcasting.  This is the jnp oracle for a fused Pallas attention
    kernel on real TPUs (masked causal blocks are computed-and-discarded;
    block skipping is a kernel-level optimisation)."""
    B, T, H, Dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = 1.0 / math.sqrt(Dh)
    qc = min(chunk, T)
    kc_sz = min(chunk, S)
    nq = (T + qc - 1) // qc
    nk = (S + kc_sz - 1) // kc_sz
    qpad, kpad = nq * qc - T, nk * kc_sz - S
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    qb = (q.reshape(B, nq, qc, Hkv, rep, Dh) * scale).astype(jnp.float32)
    kb = k.reshape(B, nk, kc_sz, Hkv, Dh)
    vb = v.reshape(B, nk, kc_sz, Hkv, Dh)

    def q_block(_, qin):
        qi, iq = qin                      # [B, qc, Hkv, rep, Dh], scalar
        q_pos = q_offset + iq * qc + jnp.arange(qc)

        def kv_block(carry, kin):
            m, l, acc = carry
            ki, vi, ik = kin
            key_pos = ik * kc_sz + jnp.arange(kc_sz)
            s = jnp.einsum(
                "bqgrd,bcgd->bqgrc", qi, ki.astype(jnp.float32)
            )  # [B, qc, Hkv, rep, kc]
            mask = jnp.ones((qc, kc_sz), bool)
            if causal:
                mask &= q_pos[:, None] >= key_pos[None, :]
            if window is not None:
                mask &= q_pos[:, None] - key_pos[None, :] < window
            mask &= (key_pos < S)[None, :]
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + pexp.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqgrc,bcgd->bqgrd", pexp, vi.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, qc, Hkv, rep), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qc, Hkv, rep), jnp.float32)
        acc0 = jnp.zeros((B, qc, Hkv, rep, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, acc0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nk)),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out

    _, blocks = jax.lax.scan(
        q_block, None, (qb.swapaxes(0, 1), jnp.arange(nq))
    )  # [nq, B, qc, Hkv, rep, Dh]
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qc, H, Dh)
    return out[:, :T].astype(q.dtype)


def decode_attention(
    q: jax.Array,        # [B, 1, H, Dh]
    k_cache: jax.Array,  # [B, S, Hkv, Dh]
    v_cache: jax.Array,  # [B, S, Hkv, Dh]
    cache_len: jax.Array | int,   # number of valid cache positions
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """One-token attention against a full KV cache (serve_step hot path)."""
    B, _, H, Dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hkv
    scale = 1.0 / math.sqrt(Dh)
    qh = (q.reshape(B, Hkv, rep, Dh) * scale).astype(jnp.float32)
    s = jnp.einsum("bgrd,bsgd->bgrs", qh, k_cache.astype(jnp.float32))
    pos = jnp.arange(S)
    mask = pos[None, :] < cache_len
    if window is not None:
        mask &= pos[None, :] >= cache_len - window
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


# --------------------------------------------------------------------- #
# flash attention with custom VJP (memory-bounded fwd AND bwd)
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class AttnOpts:
    causal: bool = True
    chunk: int = 512
    q_offset: int = 0
    unroll: int = 1   # scan unroll for roofline probes


def flash_attention(q, k, v, window=None, *, causal=True, chunk=512,
                    q_offset=0, unroll=1):
    """Differentiable flash attention.  Forward = online-softmax double
    tiling; backward = the FlashAttention recompute scheme via custom_vjp,
    saving only (q, k, v, out, lse) — O(T) residuals instead of the
    O(T²/chunk) scan residuals a naive autodiff of the tiled forward keeps.
    `window` may be a traced scalar (dynamic local:global interleave)."""
    if window is None:
        window = jnp.asarray(2**30, jnp.int32)
    opts = AttnOpts(causal=causal, chunk=chunk, q_offset=q_offset,
                    unroll=unroll)
    return _flash(q, k, v, window, opts)


def _blockify(q, k, v, chunk):
    B, T, H, Dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    qc, kc = min(chunk, T), min(chunk, S)
    nq, nk = (T + qc - 1) // qc, (S + kc - 1) // kc
    qpad, kpad = nq * qc - T, nk * kc - S
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    rep = H // Hkv
    qb = q.reshape(B, nq, qc, Hkv, rep, Dh)
    kb = k.reshape(B, nk, kc, Hkv, Dh)
    vb = v.reshape(B, nk, kc, Hkv, Dh)
    return qb, kb, vb, (B, T, S, H, Hkv, rep, Dh, qc, kc, nq, nk)


def _mask_penalty(q_pos, key_pos, S, window, causal):
    """Additive f32 penalty [qc, kc] (0 = keep, NEG_INF = mask).  Arithmetic
    masking keeps the masked-softmax a fused broadcast-add: a boolean mask
    `where`'d against the [B, qc, H, kc] score tile gets materialized at
    full tile shape by XLA (gigabytes); the [qc, kc] penalty does not."""
    m = (key_pos < S)[None, :]
    if causal:
        m = m & (q_pos[:, None] >= key_pos[None, :])
    m = m & (q_pos[:, None] - key_pos[None, :] < window)
    return jnp.where(m, 0.0, NEG_INF).astype(jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _flash(q, k, v, window, opts: "AttnOpts"):
    out, _ = _flash_fwd_impl(q, k, v, window, opts)
    return out


def _flash_fwd_impl(q, k, v, window, opts: "AttnOpts"):
    qb, kb, vb, dims = _blockify(q, k, v, opts.chunk)
    B, T, S, H, Hkv, rep, Dh, qc, kc, nq, nk = dims
    scale = 1.0 / math.sqrt(Dh)
    qb = (qb * scale).astype(jnp.float32)

    def q_block(_, qin):
        qi, iq = qin
        q_pos = opts.q_offset + iq * qc + jnp.arange(qc)

        def kv_block(carry, kin):
            m, l, acc = carry
            ki, vi, ik = kin
            key_pos = ik * kc + jnp.arange(kc)
            s = jnp.einsum("bqgrd,bcgd->bqgrc", qi, ki.astype(jnp.float32))
            pen = _mask_penalty(q_pos, key_pos, S, window, opts.causal)
            s = s + pen[None, :, None, None, :]
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + pexp.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqgrc,bcgd->bqgrd", pexp, vi.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, qc, Hkv, rep), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qc, Hkv, rep), jnp.float32)
        acc0 = jnp.zeros((B, qc, Hkv, rep, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, acc0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nk)),
            unroll=opts.unroll,
        )
        o = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (o, lse)

    _, (blocks, lses) = jax.lax.scan(
        q_block, None, (qb.swapaxes(0, 1), jnp.arange(nq)),
        unroll=opts.unroll,
    )
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qc, H, Dh)
    out = out[:, :T].astype(q.dtype)
    lse = lses.transpose(1, 0, 2, 3, 4).reshape(B, nq * qc, Hkv, rep)[:, :T]
    return out, lse


def _flash_fwd(q, k, v, window, opts: "AttnOpts"):
    out, lse = _flash_fwd_impl(q, k, v, window, opts)
    return out, (q, k, v, window, out, lse)


def _flash_bwd(opts: "AttnOpts", res, dout):
    q, k, v, window, out, lse = res
    qb, kb, vb, dims = _blockify(q, k, v, opts.chunk)
    B, T, S, H, Hkv, rep, Dh, qc, kc, nq, nk = dims
    scale = 1.0 / math.sqrt(Dh)
    qb = (qb * scale).astype(jnp.float32)
    pad_t = nq * qc - T

    def padT(x):
        return jnp.pad(x, ((0, 0), (0, pad_t)) + ((0, 0),) * (x.ndim - 2)) \
            if pad_t else x

    dob = padT(dout.astype(jnp.float32)).reshape(B, nq, qc, Hkv, rep, Dh)
    lseb = padT(lse).reshape(B, nq, qc, Hkv, rep)
    # D_i = rowsum(dO ∘ O)
    Dfull = padT((dout.astype(jnp.float32) * out.astype(jnp.float32)).sum(-1)
                 .reshape(B, T, Hkv, rep))
    Db = Dfull.reshape(B, nq, qc, Hkv, rep)

    def probs(qi, ki, iq, ik):
        q_pos = opts.q_offset + iq * qc + jnp.arange(qc)
        key_pos = ik * kc + jnp.arange(kc)
        s = jnp.einsum("bqgrd,bcgd->bqgrc", qi, ki.astype(jnp.float32))
        pen = _mask_penalty(q_pos, key_pos, S, window, opts.causal)
        return s + pen[None, :, None, None, :]

    # Fused single pass (FlashAttention-2 style): outer scan over KV blocks
    # carrying the full blocked dQ accumulator; dK/dV emitted per KV block.
    # One [T, H, Dh] f32 dq buffer total and each (q, kv) tile's P matrix is
    # computed exactly once in the backward.
    def kv_block(dq_all, kin):
        ki, vi, ik = kin

        def q_block(carry, qin):
            dk, dv, = carry
            qi, doi, lsei, Di, dqi, iq = qin
            s = probs(qi, ki, iq, ik)
            p = jnp.exp(s - lsei[..., None])
            dv = dv + jnp.einsum("bqgrc,bqgrd->bcgd", p, doi)
            dp = jnp.einsum("bqgrd,bcgd->bqgrc", doi, vi.astype(jnp.float32))
            ds = p * (dp - Di[..., None])
            dk = dk + jnp.einsum("bqgrc,bqgrd->bcgd", ds, qi)
            dqi = dqi + jnp.einsum("bqgrc,bcgd->bqgrd", ds,
                                   ki.astype(jnp.float32))
            return (dk, dv), dqi

        dk0 = jnp.zeros((B, kc, Hkv, Dh), jnp.float32)
        dv0 = jnp.zeros((B, kc, Hkv, Dh), jnp.float32)
        (dk, dv), dq_all = jax.lax.scan(
            q_block, (dk0, dv0),
            (qb.swapaxes(0, 1), dob.swapaxes(0, 1), lseb.swapaxes(0, 1),
             Db.swapaxes(0, 1), dq_all, jnp.arange(nq)),
            unroll=opts.unroll,
        )
        return dq_all, (dk, dv)

    dq0 = jnp.zeros((nq, B, qc, Hkv, rep, Dh), jnp.float32)
    dqb, (dkb, dvb) = jax.lax.scan(
        kv_block, dq0,
        (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nk)),
        unroll=opts.unroll,
    )
    dq = dqb.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qc, H, Dh)[:, :T]
    dq = (dq * scale).astype(q.dtype)
    dk = dkb.transpose(1, 0, 2, 3, 4).reshape(B, nk * kc, Hkv, Dh)[:, :S]
    dv = dvb.transpose(1, 0, 2, 3, 4).reshape(B, nk * kc, Hkv, Dh)[:, :S]
    return dq, dk.astype(k.dtype), dv.astype(v.dtype), None


_flash.defvjp(_flash_fwd, _flash_bwd)


# --------------------------------------------------------------------- #
# loss — chunked softmax cross-entropy (never materializes [T, vocab])
# --------------------------------------------------------------------- #
def chunked_xent(
    h: jax.Array,          # [B, T, D] final hidden states
    emb: jax.Array,        # [V, D] (tied LM head)
    labels: jax.Array,     # [B, T] int32
    *,
    n_chunks: int = 8,
    unroll: int = 1,
) -> jax.Array:
    B, T, D = h.shape
    assert T % n_chunks == 0, "seq len must divide loss chunks"
    hc = h.reshape(B, n_chunks, T // n_chunks, D).swapaxes(0, 1)
    lc = labels.reshape(B, n_chunks, T // n_chunks).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(hx, lx):
        # rematerialized in bwd: the [chunk, vocab] logits/probs are never
        # saved — O(T·V) residuals would dominate HBM otherwise
        logits = jnp.einsum(
            "btd,vd->btv", hx.astype(jnp.float32), emb.astype(jnp.float32)
        )
        logits = shard_hint(logits, "fsdp", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, lx[..., None].astype(jnp.int32), axis=-1
        )[..., 0]
        return (lse - gold).sum()

    def body(tot, inp):
        hx, lx = inp
        return tot + chunk_loss(hx, lx), None

    tot, _ = jax.lax.scan(
        body, jnp.zeros((), jnp.float32), (hc, lc), unroll=unroll
    )
    return tot / (B * T)
