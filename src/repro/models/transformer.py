"""Decoder-only LM family: dense + MoE, GQA, qk-norm, RoPE, local:global.

Covers the five assigned LM architectures (qwen3-moe-235b-a22b, grok-1-314b,
mistral-nemo-12b, qwen3-32b, gemma3-1b) from one configurable block:

  * GQA with explicit d_head (head count never needs to equal d_model/d_head),
  * optional per-head qk RMS-norm (qwen3),
  * optional sliding-window : global layer interleave (gemma3's 5:1, window
    as a *dynamic* per-layer scalar so the layer stack stays a single
    ``lax.scan`` — one compiled block regardless of the pattern),
  * MoE FFN with top-k routing and capacity-bucketed dispatch: sort-by-expert
    + static-capacity scatter into an [E, C, D] buffer sharded (expert →
    `model` axis, capacity → fsdp axes).  GSPMD materializes the implied
    token all_to_all — the classic expert-parallel schedule,
  * chunked flash-style attention and chunked LM-head loss: nothing
    quadratic or [T, vocab]-sized is ever materialized.

Sharding: 2D-sharded weights (fsdp × model) give ZeRO-3/FSDP behaviour via
GSPMD; `fsdp` is ("data",) on the single-pod mesh and ("pod", "data") on the
multi-pod mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common as C


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # MoE ( d_ff is the per-expert hidden when moe_experts > 0 )
    moe_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # attention flavour
    qk_norm: bool = False
    local_window: int = 0     # sliding-window size (0 = full attention)
    global_every: int = 0     # every k-th layer is global (gemma3: 6)
    rope_theta: float = 10_000.0
    # numerics / scheduling
    dtype: Any = jnp.bfloat16
    attn_chunk: int = 1024
    loss_chunks: int = 8
    remat: bool = True
    aux_loss_coef: float = 0.01
    # roofline probes: unroll every scan so cost_analysis counts real work
    probe_unroll: bool = False

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    def n_params(self) -> int:
        a = self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
        a += self.n_heads * self.d_head * self.d_model
        if self.is_moe:
            f = self.moe_experts * 3 * self.d_model * self.d_ff
            f += self.d_model * self.moe_experts
        else:
            f = 3 * self.d_model * self.d_ff
        return self.n_layers * (a + f) + self.vocab * self.d_model

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        a = self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
        a += self.n_heads * self.d_head * self.d_model
        if self.is_moe:
            f = self.moe_top_k * 3 * self.d_model * self.d_ff
            f += self.d_model * self.moe_experts
        else:
            f = 3 * self.d_model * self.d_ff
        return self.n_layers * (a + f) + self.vocab * self.d_model


# --------------------------------------------------------------------- #
# parameter specs
# --------------------------------------------------------------------- #
def param_specs(cfg: TransformerConfig, fsdp=("data",)) -> Dict[str, Any]:
    L, D, H, Hkv, dh, F, V = (
        cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
        cfg.d_ff, cfg.vocab,
    )
    f = tuple(fsdp)
    S = C.ParamSpec
    dt = cfg.dtype
    specs: Dict[str, Any] = {
        "embed": S((V, D), dt, P("model", f)),
        "final_norm": S((D,), jnp.float32, P(None), init="zeros"),
        "attn": {
            "norm": S((L, D), jnp.float32, P(None, None), init="zeros"),
            "wq": S((L, D, H * dh), dt, P(None, f, "model")),
            "wk": S((L, D, Hkv * dh), dt, P(None, f, None)),
            "wv": S((L, D, Hkv * dh), dt, P(None, f, None)),
            "wo": S((L, H * dh, D), dt, P(None, "model", f)),
        },
    }
    if cfg.qk_norm:
        specs["attn"]["q_norm"] = S((L, dh), jnp.float32, P(None, None), init="zeros")
        specs["attn"]["k_norm"] = S((L, dh), jnp.float32, P(None, None), init="zeros")
    if cfg.is_moe:
        E = cfg.moe_experts
        specs["ffn"] = {
            "norm": S((L, D), jnp.float32, P(None, None), init="zeros"),
            "router": S((L, D, E), jnp.float32, P(None, f, None)),
            "w_gate": S((L, E, D, F), dt, P(None, "model", f, None)),
            "w_up": S((L, E, D, F), dt, P(None, "model", f, None)),
            "w_down": S((L, E, F, D), dt, P(None, "model", None, f)),
        }
    else:
        specs["ffn"] = {
            "norm": S((L, D), jnp.float32, P(None, None), init="zeros"),
            "w_gate": S((L, D, F), dt, P(None, f, "model")),
            "w_up": S((L, D, F), dt, P(None, f, "model")),
            "w_down": S((L, F, D), dt, P(None, "model", f)),
        }
    return specs


# --------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------- #
def _layer_window(cfg: TransformerConfig, layer_idx: jax.Array) -> Optional[jax.Array]:
    """Dynamic per-layer sliding window; None if the config is all-global."""
    if not cfg.local_window:
        return None
    if not cfg.global_every:
        return jnp.asarray(cfg.local_window, jnp.int32)
    is_global = (layer_idx % cfg.global_every) == (cfg.global_every - 1)
    return jnp.where(is_global, jnp.int32(2**30), jnp.int32(cfg.local_window))


def _attention(x, lp, cfg: TransformerConfig, layer_idx, positions,
               kv_cache=None, cache_len=None):
    B, T, D = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    h = C.rms_norm(x, lp["norm"])
    q = jnp.einsum("btd,dh->bth", h, lp["wq"].astype(h.dtype))
    k = jnp.einsum("btd,dh->bth", h, lp["wk"].astype(h.dtype))
    v = jnp.einsum("btd,dh->bth", h, lp["wv"].astype(h.dtype))
    q = q.reshape(B, T, H, dh)
    k = k.reshape(B, T, Hkv, dh)
    v = v.reshape(B, T, Hkv, dh)
    if cfg.qk_norm:
        q = C.rms_norm(q, lp["q_norm"])
        k = C.rms_norm(k, lp["k_norm"])
    q = C.rope(q, positions, cfg.rope_theta)
    k = C.rope(k, positions, cfg.rope_theta)
    window = _layer_window(cfg, layer_idx)

    if kv_cache is None:
        o = C.flash_attention(
            q, k, v, window, causal=True, chunk=cfg.attn_chunk,
            unroll=64 if cfg.probe_unroll else 1,
        )
        new_cache = None
    else:
        kc, vc = kv_cache
        pos0 = cache_len  # scalar: write position
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos0, 0, 0))
        o = C.decode_attention(q, kc, vc, cache_len + T, window=window)
        new_cache = (kc, vc)
    o = o.reshape(B, T, H * dh)
    out = jnp.einsum("bth,hd->btd", o, lp["wo"].astype(o.dtype))
    return x + out, new_cache


def _dense_ffn(x, lp):
    h = C.rms_norm(x, lp["norm"])
    return x + C.swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])


def _moe_ffn(x, lp, cfg: TransformerConfig):
    """Top-k routed MoE with static capacity (sort + scatter dispatch)."""
    B, T, D = x.shape
    E, K = cfg.moe_experts, cfg.moe_top_k
    N = B * T
    h = C.rms_norm(x, lp["norm"])
    hf = h.reshape(N, D)
    logits = jnp.einsum("nd,de->ne", hf.astype(jnp.float32), lp["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                  # [N, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros(E, jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (N * K)
    )
    aux = cfg.aux_loss_coef * E * jnp.sum(me * ce)

    # dispatch: rank within expert via sort
    NA = N * K
    cap = int(max(1, round(NA / E * cfg.capacity_factor)))
    flat_e = idx.reshape(NA)
    token_of = jnp.arange(NA, dtype=jnp.int32) // K
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=flat_e.dtype))
    rank_sorted = jnp.arange(NA, dtype=jnp.int32) - starts[sorted_e]
    rank = jnp.zeros(NA, jnp.int32).at[order].set(rank_sorted)
    keep = rank < cap
    slot = jnp.where(keep, flat_e * cap + rank, E * cap)

    buf = jnp.zeros((E * cap + 1, D), h.dtype).at[slot].add(
        jnp.where(keep[:, None], hf[token_of], 0)
    )[: E * cap].reshape(E, cap, D)
    # expert-parallel dispatch buffer: experts over `model`, capacity over
    # the fsdp axes — GSPMD materializes the token all_to_all
    buf = C.shard_hint(buf, "model", "fsdp", None)

    # §Perf H1: force the FSDP schedule on the expert matmuls — gather the
    # (cheap) 2-D-sharded weight shards per layer instead of letting GSPMD
    # all-reduce activation-sized [E, cap, F] partial sums (contracting-dim
    # sharding).  When experts don't divide the model axis (grok: E=8 < 16)
    # shard F/D over `model` instead so compute still splits 256 ways.
    ms = C.hint_axis_size("model") or 1
    if E % max(ms, 1) == 0:
        wg = C.shard_hint(lp["w_gate"], "model", None, None)
        wu = C.shard_hint(lp["w_up"], "model", None, None)
        wd = C.shard_hint(lp["w_down"], "model", None, None)
    else:
        wg = C.shard_hint(lp["w_gate"], None, None, "model")
        wu = C.shard_hint(lp["w_up"], None, None, "model")
        wd = C.shard_hint(lp["w_down"], None, None, "model")

    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(buf.dtype))
    act = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    out = jnp.einsum("ecf,efd->ecd", act, wd.astype(buf.dtype))

    out = C.shard_hint(out, "model", "fsdp", None)
    out_flat = out.reshape(E * cap, D)
    y_assign = jnp.where(
        keep[:, None], out_flat[jnp.minimum(slot, E * cap - 1)], 0
    ) * gate.reshape(NA)[:, None].astype(h.dtype)
    y_assign = C.shard_hint(y_assign, "fsdp", None)
    # §Perf H1.2: token_of = assignment // K is CONTIGUOUS, so the combine
    # is a reshape + sum over K — not a scatter.  (The scatter form made
    # GSPMD materialize dense [N, D] partials and all-reduce them.)
    y = y_assign.reshape(N, K, D).sum(axis=1)
    y = C.shard_hint(y, "fsdp", None)
    return x + y.reshape(B, T, D), aux


def forward(
    params: Dict[str, Any],
    tokens: jax.Array,            # [B, T] int32
    cfg: TransformerConfig,
    *,
    positions: Optional[jax.Array] = None,
    kv_caches: Optional[Tuple[jax.Array, jax.Array]] = None,  # [L, B, S, Hkv, dh] x2
    cache_len: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Returns (hidden [B,T,D], aux_loss, new_kv_caches)."""
    B, T = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    x = params["embed"][tokens].astype(cfg.dtype)

    decode = kv_caches is not None

    def block(carry, layer):
        x = carry
        # Megatron-style sequence parallelism: the residual stream carried
        # between blocks (and saved by remat) is sharded over `model` along
        # the sequence dim — the dominant per-layer remat residual shrinks
        # by the model-axis factor.
        if not decode:
            x = C.shard_hint(x, "fsdp", "model", None)
        lp_attn, lp_ffn, layer_idx, kc, vc = layer
        if decode:
            x, (kc, vc) = _attention(
                x, lp_attn, cfg, layer_idx, positions,
                kv_cache=(kc, vc), cache_len=cache_len,
            )
        else:
            x, _ = _attention(x, lp_attn, cfg, layer_idx, positions)
        if cfg.is_moe:
            x, aux = _moe_ffn(x, lp_ffn, cfg)
        else:
            x = _dense_ffn(x, lp_ffn)
            aux = jnp.zeros((), jnp.float32)
        return x, (aux, kc, vc)

    blk = jax.checkpoint(block) if (cfg.remat and not decode) else block
    layer_ids = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    if decode:
        kcs, vcs = kv_caches
        xs = (params["attn"], params["ffn"], layer_ids, kcs, vcs)
    else:
        dummy = jnp.zeros((cfg.n_layers, 1), cfg.dtype)
        xs = (params["attn"], params["ffn"], layer_ids, dummy, dummy)
    x, (auxes, kcs, vcs) = jax.lax.scan(
        blk, x, xs, unroll=cfg.n_layers if cfg.probe_unroll else 1
    )
    x = C.rms_norm(x, params["final_norm"])
    new_caches = (kcs, vcs) if decode else None
    return x, auxes.sum(), new_caches


# --------------------------------------------------------------------- #
# steps
# --------------------------------------------------------------------- #
def loss_fn(params, batch, cfg: TransformerConfig):
    h, aux, _ = forward(params, batch["tokens"], cfg)
    xent = C.chunked_xent(
        h, params["embed"], batch["labels"], n_chunks=cfg.loss_chunks,
        unroll=cfg.loss_chunks if cfg.probe_unroll else 1,
    )
    return xent + aux


def make_kv_cache_specs(cfg: TransformerConfig, batch: int, max_seq: int,
                        fsdp=("data",), shard_seq: bool = False):
    """ShapeDtypeStructs + PartitionSpecs for the decode KV cache."""
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
    if shard_seq:
        pspec = P(None, None, "model", None, None)
    else:
        pspec = P(None, tuple(fsdp), None, None, "model" if cfg.d_head % 8 == 0 else None)
    sds = jax.ShapeDtypeStruct(shape, cfg.dtype)
    return (sds, sds), (pspec, pspec)


def serve_step(params, kv_caches, tokens, cache_len, cfg: TransformerConfig):
    """One decode step: tokens [B, 1] + cache → (next-token logits, cache)."""
    B = tokens.shape[0]
    positions = jnp.broadcast_to(cache_len, (B, 1)) + jnp.zeros(
        (B, 1), jnp.int32
    )
    h, _, new_caches = forward(
        params, tokens, cfg, positions=positions,
        kv_caches=kv_caches, cache_len=cache_len,
    )
    logits = jnp.einsum(
        "btd,vd->btv", h.astype(jnp.float32),
        params["embed"].astype(jnp.float32),
    )
    return logits[:, -1], new_caches


def prefill_step(params, tokens, cfg: TransformerConfig):
    """Inference prefill: full forward, returns last hidden + logits."""
    h, _, _ = forward(params, tokens, cfg)
    logits = jnp.einsum(
        "bd,vd->bv", h[:, -1].astype(jnp.float32),
        params["embed"].astype(jnp.float32),
    )
    return logits
