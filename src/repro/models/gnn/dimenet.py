"""DimeNet (Gasteiger et al. [arXiv:2003.03123]) — directional message
passing over edge messages with a triplet (angular) interaction.

Kernel regime: triplet gather — messages live on *edges*; each interaction
block aggregates over wedges (k→j→i) with a radial×angular basis and a
bilinear contraction (n_bilinear=8 down-projection as in DimeNet++).

TPU adaptation (DESIGN.md §5): the triplet set is capped at a static budget
``n_triplets`` (full Σ deg² enumeration is intractable for the 100M-edge
assigned shapes); triplets are sampled/truncated per in-edge, the standard
batched-angular-GNN practice.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ParamSpec
from repro.models.gnn import common as G


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    d_feat: int = 16          # species/feature input dim (projected in)
    cutoff: float = 5.0
    dtype: Any = jnp.float32
    probe_unroll: bool = False


def param_specs(cfg: DimeNetConfig, fsdp=("data",)) -> Dict[str, Any]:
    S = ParamSpec
    d, nb = cfg.d_hidden, cfg.n_blocks
    nsr = cfg.n_spherical * cfg.n_radial
    return {
        "embed_node": S((cfg.d_feat, d), cfg.dtype, P(None, "model")),
        "embed_rbf": S((cfg.n_radial, d), cfg.dtype, P(None, None)),
        "embed_msg": S((3 * d, d), cfg.dtype, P(None, "model")),
        "blocks": {
            "w_msg": S((nb, d, d), cfg.dtype, P(None, None, "model")),
            "w_down": S((nb, d, cfg.n_bilinear), cfg.dtype, P(None, None, None)),
            "w_sbf": S((nb, nsr, cfg.n_bilinear), cfg.dtype, P(None, None, None)),
            "w_up": S((nb, cfg.n_bilinear, d), cfg.dtype, P(None, None, "model")),
            "w_rbf_gate": S((nb, cfg.n_radial, d), cfg.dtype, P(None, None, None)),
            "w_out1": S((nb, d, d), cfg.dtype, P(None, "model", None)),
            "w_out2": S((nb, d, d), cfg.dtype, P(None, None, "model")),
        },
        "head_w1": S((d, d), cfg.dtype, P(None, "model")),
        "head_w2": S((d, 1), cfg.dtype, P("model", None)),
    }


def forward(params, batch, cfg: DimeNetConfig) -> jax.Array:
    """batch: pos [N,3], node_feat [N,F], row/col [E] (sentinel pads),
    triplets [T, 2] = (in-edge k→j, out-edge j→i), batch_id [N] → energies
    per graph [n_graphs]."""
    n = batch["node_feat"].shape[0]
    row, col = batch["row"], batch["col"]
    E = row.shape[0]
    emask = row < n
    posp = jnp.concatenate([batch["pos"], jnp.zeros((1, 3), cfg.dtype)])
    vec = posp[col] - posp[row]
    dist = jnp.linalg.norm(vec + (~emask[:, None]) * 1.0, axis=-1)
    dirs = vec / jnp.maximum(dist[:, None], 1e-6)
    rbf = G.radial_basis(dist, cfg.n_radial, cfg.cutoff) * emask[:, None]

    h = batch["node_feat"].astype(cfg.dtype) @ params["embed_node"]
    hp = jnp.concatenate([h, jnp.zeros((1, cfg.d_hidden), h.dtype)])
    m = jax.nn.silu(
        jnp.concatenate(
            [hp[row], hp[col], rbf @ params["embed_rbf"]], axis=-1
        ) @ params["embed_msg"]
    ) * emask[:, None]

    # triplet geometry: angle between in-edge and out-edge directions
    t_in, t_out = batch["triplets"][:, 0], batch["triplets"][:, 1]
    tmask = (t_in < E) & (t_out < E)
    ti = jnp.minimum(t_in, E - 1)
    to = jnp.minimum(t_out, E - 1)
    cos_a = (-dirs[ti] * dirs[to]).sum(-1).clip(-1.0, 1.0)
    angle = jnp.arccos(cos_a)
    sbf = (
        G.angular_basis(angle, cfg.n_spherical)[:, :, None]
        * G.radial_basis(dist[ti], cfg.n_radial, cfg.cutoff)[:, None, :]
    ).reshape(-1, cfg.n_spherical * cfg.n_radial) * tmask[:, None]

    node_out = jnp.zeros((n, cfg.d_hidden), cfg.dtype)

    def block(carry, bp):
        m, node_out = carry
        # bilinear triplet interaction (DimeNet++ down/up projection)
        m_in = (m[ti] @ bp["w_down"])                       # [T, nbil]
        tmsg = m_in * (sbf @ bp["w_sbf"])                   # [T, nbil]
        agg = G.scatter_sum(
            jnp.where(tmask[:, None], tmsg, 0), to, E
        ) @ bp["w_up"]                                      # [E, d]
        m_new = jax.nn.silu(m @ bp["w_msg"] + agg) * emask[:, None]
        m = m + m_new
        gate = rbf @ bp["w_rbf_gate"]                       # [E, d]
        contrib = G.scatter_sum(m * gate, col, n)
        node_out = node_out + jax.nn.silu(contrib @ bp["w_out1"]) @ bp["w_out2"]
        return (m, node_out), None

    (m, node_out), _ = jax.lax.scan(
        block, (m, node_out), params["blocks"],
        unroll=cfg.n_blocks if cfg.probe_unroll else 1,
    )
    per_node = jax.nn.silu(node_out @ params["head_w1"]) @ params["head_w2"]
    energies = G.scatter_sum(per_node, batch["batch_id"], batch["n_graphs"])
    return energies[:, 0]


def loss_fn(params, batch, cfg: DimeNetConfig) -> jax.Array:
    e = forward(params, batch, cfg)
    return jnp.mean((e - batch["energy"]) ** 2)
