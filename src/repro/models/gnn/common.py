"""GNN substrate: masked message passing over padded edge lists.

JAX has no CSR/CSC sparse (BCOO only) — message passing is implemented as
``gather → segment_sum/max → update`` over an edge-index array, the same
regime as the MWIS rule sweeps (and served by the same `segment_coo` Pallas
kernel on TPU).  All graphs are padded to static shapes: edge targets use a
sentinel node `n` whose row absorbs padding writes.

Distribution: node arrays shard rows over the fsdp axes, edges shard over
the same; cross-shard gathers become GSPMD collectives (the halo exchange
of the paper, implicit).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ops import segment_max, segment_sum

from repro.models.common import ParamSpec


def scatter_sum(vals: jax.Array, seg: jax.Array, n: int) -> jax.Array:
    """segment-sum with one sentinel row absorbed ([n+1] then sliced)."""
    out = segment_sum(vals, seg, num_segments=n + 1)
    return out[:n]


def scatter_mean(vals: jax.Array, seg: jax.Array, n: int,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    ones = jnp.ones(vals.shape[:1], vals.dtype)
    if mask is not None:
        vals = jnp.where(mask[:, None], vals, 0) if vals.ndim > 1 else \
            jnp.where(mask, vals, 0)
        ones = jnp.where(mask, ones, 0)
    s = scatter_sum(vals, seg, n)
    c = segment_sum(ones, seg, num_segments=n + 1)[:n]
    return s / jnp.maximum(c[:, None] if s.ndim > 1 else c, 1e-9)


def scatter_max(vals: jax.Array, seg: jax.Array, n: int) -> jax.Array:
    out = segment_max(vals, seg, num_segments=n + 1)
    return out[:n]


def mlp_specs(dims, pspecs=None, prefix="") -> Dict[str, Any]:
    specs = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        specs[f"{prefix}w{i}"] = ParamSpec((a, b), jnp.float32)
        specs[f"{prefix}b{i}"] = ParamSpec((b,), jnp.float32, init="zeros")
    return specs


def mlp_apply(params: Dict[str, Any], x: jax.Array, n_layers: int,
              act=jax.nn.relu, prefix="", final_act: bool = False) -> jax.Array:
    for i in range(n_layers):
        x = x @ params[f"{prefix}w{i}"] + params[f"{prefix}b{i}"]
        if i < n_layers - 1 or final_act:
            x = act(x)
    return x


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


def node_xent_loss(logits: jax.Array, labels: jax.Array,
                   mask: jax.Array) -> jax.Array:
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[:, None].astype(jnp.int32), axis=-1
    )[:, 0]
    per = (lse - gold) * mask
    return per.sum() / jnp.maximum(mask.sum(), 1.0)


def radial_basis(dist: jax.Array, n_radial: int, cutoff: float = 5.0) -> jax.Array:
    """DimeNet's spherical-Bessel-flavoured radial basis (sin(nπd/c)/d)."""
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    d = jnp.maximum(dist[..., None], 1e-6)
    env = _envelope(dist / cutoff)[..., None]
    return env * jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d


def _envelope(x: jax.Array, p: int = 6) -> jax.Array:
    """Smooth cutoff envelope (DimeNet eq. 8)."""
    a = -(p + 1) * (p + 2) / 2.0
    b = p * (p + 2.0)
    c = -p * (p + 1) / 2.0
    e = 1.0 / jnp.maximum(x, 1e-6) + a * x ** (p - 1) + b * x**p + c * x ** (p + 1)
    return jnp.where(x < 1.0, e, 0.0)


def angular_basis(angle: jax.Array, n_spherical: int) -> jax.Array:
    """cos(k·θ) Chebyshev-flavoured angular basis (SBF stand-in)."""
    k = jnp.arange(n_spherical, dtype=jnp.float32)
    return jnp.cos(k * angle[..., None])


def spherical_harmonics_dirs(dirs: jax.Array, l_max: int) -> jax.Array:
    """Real SH-flavoured direction features up to l_max: [E, (l_max+1)^2].

    Uses associated-Legendre recursion on cosθ with cos/sin(mφ) factors —
    the standard real-SH construction (unnormalised; a per-l learned scale
    in the model absorbs normalisation).
    """
    x, y, z = dirs[..., 0], dirs[..., 1], dirs[..., 2]
    r_xy = jnp.sqrt(jnp.maximum(x * x + y * y, 1e-12))
    cos_t = z
    phi = jnp.arctan2(y, x)
    # associated Legendre P_l^m(cosθ) by recursion
    P = {}
    P[(0, 0)] = jnp.ones_like(cos_t)
    sin_t = jnp.sqrt(jnp.maximum(1.0 - cos_t * cos_t, 0.0))
    for m in range(1, l_max + 1):
        P[(m, m)] = -(2 * m - 1) * sin_t * P[(m - 1, m - 1)]
    for m in range(0, l_max):
        P[(m + 1, m)] = (2 * m + 1) * cos_t * P[(m, m)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            P[(l, m)] = (
                (2 * l - 1) * cos_t * P[(l - 1, m)] - (l + m - 1) * P[(l - 2, m)]
            ) / (l - m)
    feats = []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            if m < 0:
                feats.append(P[(l, -m)] * jnp.sin(-m * phi))
            elif m == 0:
                feats.append(P[(l, 0)])
            else:
                feats.append(P[(l, m)] * jnp.cos(m * phi))
    return jnp.stack(feats, axis=-1)
