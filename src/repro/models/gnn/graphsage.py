"""GraphSAGE (Hamilton et al. [arXiv:1706.02216]) — mean aggregator,
2 layers, fanout sampling (25-10 for the Reddit config).

    h'_v = ReLU( W_self h_v + W_nbr · mean_{u∈sample(N(v))} h_u )

The sampled-training shape (`minibatch_lg`) consumes subgraphs produced by
:mod:`repro.graphs.sampler`; full-batch shapes pass the whole edge list.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ParamSpec
from repro.models.gnn import common as G


@dataclasses.dataclass(frozen=True)
class GraphSAGEConfig:
    name: str = "graphsage-reddit"
    n_layers: int = 2
    d_hidden: int = 128
    d_feat: int = 602
    n_classes: int = 41
    sample_sizes: tuple = (25, 10)
    dtype: Any = jnp.float32


def param_specs(cfg: GraphSAGEConfig, fsdp=("data",)) -> Dict[str, Any]:
    S = ParamSpec
    specs: Dict[str, Any] = {}
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        d_out = cfg.d_hidden
        specs[f"l{i}_self"] = S((d_in, d_out), cfg.dtype, P(None, "model"))
        specs[f"l{i}_nbr"] = S((d_in, d_out), cfg.dtype, P(None, "model"))
        specs[f"l{i}_b"] = S((d_out,), cfg.dtype, P(None), init="zeros")
        d_in = d_out
    specs["out_w"] = S((d_in, cfg.n_classes), cfg.dtype, P("model", None))
    specs["out_b"] = S((cfg.n_classes,), cfg.dtype, P(None), init="zeros")
    return specs


def forward(params, batch, cfg: GraphSAGEConfig) -> jax.Array:
    n = batch["node_feat"].shape[0]
    row, col = batch["row"], batch["col"]
    emask = row < n
    h = batch["node_feat"].astype(cfg.dtype)
    for i in range(cfg.n_layers):
        hp = jnp.concatenate([h, jnp.zeros((1, h.shape[1]), h.dtype)])
        agg = G.scatter_mean(hp[row], col, n, mask=emask)
        h = jax.nn.relu(
            h @ params[f"l{i}_self"] + agg @ params[f"l{i}_nbr"]
            + params[f"l{i}_b"]
        )
        # L2 normalisation as in the paper
        h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
    return h @ params["out_w"] + params["out_b"]


def loss_fn(params, batch, cfg: GraphSAGEConfig) -> jax.Array:
    logits = forward(params, batch, cfg)
    return G.node_xent_loss(logits, batch["labels"], batch["label_mask"])
