"""EquiformerV2-style equivariant graph attention [arXiv:2306.12059].

Structure reproduced (the part that matters for systems/roofline work):

  * node features are irrep channels x ∈ [N, n_lm, C] with l ≤ l_max = 6,
  * the eSCN m_max trick: only |m| ≤ m_max = 2 components are carried
    (n_lm = Σ_l (2·min(l, m_max)+1) = 29 instead of 49 — the O(L⁶)→O(L³)
    memory/compute saver of eSCN),
  * per-edge: gather source irreps, modulate by real-SH direction features
    and a radial basis, mix channels with per-l weights (the SO(2)
    block-diagonal convolution pattern),
  * multi-head attention over incoming edges: scalar-channel scores →
    segment-softmax per destination (SDDMM → edge-softmax → SpMM regime),
  * gated nonlinearity: l=0 scalars gate all higher-l channels.

Honest simplification (DESIGN.md §5): messages are formed in the global
frame with SH modulation instead of per-edge Wigner rotations into the
edge-aligned frame, so strict SO(3) equivariance is not numerically
enforced.  Compute graph shape, memory traffic and collective pattern —
what the dry-run/roofline grade — match the eSCN schedule.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
from jax.ops import segment_max
from jax.sharding import PartitionSpec as P

from repro.models.common import ParamSpec, shard_hint
from repro.models.gnn import common as G


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_radial: int = 8
    d_feat: int = 16
    cutoff: float = 5.0
    dtype: Any = jnp.float32
    # per-edge irrep messages are [E, n_lm, d] — for the 100M-edge assigned
    # shapes that is TBs if materialized at once; edges are processed in
    # rematerialized chunks (two passes: softmax stats, then aggregation)
    edge_chunk: int = 1 << 21
    probe_unroll: bool = False
    # §Perf H2: apply the per-l channel mixing on NODES before gathering
    # (linear ⇒ identical result; E/N ≈ 25 × fewer matmul flops) and carry
    # gathered activations in bf16 (halves gather/all-gather bytes)
    transform_then_gather: bool = True
    act_dtype: Any = jnp.bfloat16

    @property
    def lm_count(self) -> int:
        return sum(2 * min(l, self.m_max) + 1 for l in range(self.l_max + 1))


def lm_maps(cfg: EquiformerV2Config):
    """(full-SH index per kept component [n_lm], l per kept component)."""
    keep: List[int] = []
    l_of: List[int] = []
    for l in range(cfg.l_max + 1):
        for m in range(-l, l + 1):
            if abs(m) <= cfg.m_max:
                keep.append(l * l + l + m)
                l_of.append(l)
    return jnp.asarray(keep, jnp.int32), jnp.asarray(l_of, jnp.int32)


def param_specs(cfg: EquiformerV2Config, fsdp=("data",)) -> Dict[str, Any]:
    S = ParamSpec
    L, d, H = cfg.n_layers, cfg.d_hidden, cfg.n_heads
    n_l = cfg.l_max + 1
    return {
        "embed_node": S((cfg.d_feat, d), cfg.dtype, P(None, "model")),
        "layers": {
            # per-l channel mixers (SO(2)-conv block-diagonal pattern)
            "w_src": S((L, n_l, d, d), cfg.dtype, P(None, None, None, "model")),
            "w_msg": S((L, n_l, d, d), cfg.dtype, P(None, None, "model", None)),
            "w_rad": S((L, cfg.n_radial, n_l * d), cfg.dtype, P(None, None, None)),
            # attention scores from scalar channels
            "w_att_src": S((L, d, H), cfg.dtype, P(None, None, None)),
            "w_att_dst": S((L, d, H), cfg.dtype, P(None, None, None)),
            "w_att_rbf": S((L, cfg.n_radial, H), cfg.dtype, P(None, None, None)),
            # gated nonlinearity
            "w_gate": S((L, d, n_l * d), cfg.dtype, P(None, None, None)),
            "ln_g": S((L, d), cfg.dtype, P(None, None), init="ones"),
            "ln_b": S((L, d), cfg.dtype, P(None, None), init="zeros"),
        },
        "head_w1": S((d, d), cfg.dtype, P(None, "model")),
        "head_w2": S((d, 1), cfg.dtype, P("model", None)),
    }


def forward(params, batch, cfg: EquiformerV2Config) -> jax.Array:
    n = batch["node_feat"].shape[0]
    row, col = batch["row"], batch["col"]
    E = row.shape[0]
    keep_idx, l_of = lm_maps(cfg)
    n_lm = cfg.lm_count
    d, H = cfg.d_hidden, cfg.n_heads

    # edge chunking: [E] arrays -> [n_chunks, ec]
    ec = min(cfg.edge_chunk, E)
    n_chunks = (E + ec - 1) // ec
    pad_e = n_chunks * ec - E

    def padE(a, fill):
        return jnp.concatenate([a, jnp.full((pad_e,), fill, a.dtype)]) \
            if pad_e else a

    row_c = padE(row, n).reshape(n_chunks, ec)
    col_c = padE(col, n).reshape(n_chunks, ec)

    posp = jnp.concatenate([batch["pos"], jnp.zeros((1, 3), cfg.dtype)])
    h0 = batch["node_feat"].astype(cfg.dtype) @ params["embed_node"]  # [N, d]
    x = jnp.zeros((n, n_lm, d), cfg.dtype).at[:, 0, :].set(h0)

    def edge_geometry(rows, cols):
        emask = rows < n
        vec = posp[cols] - posp[rows]
        dist = jnp.linalg.norm(vec + (~emask[:, None]) * 1.0, axis=-1)
        dirs = vec / jnp.maximum(dist[:, None], 1e-6)
        rbf = G.radial_basis(dist, cfg.n_radial, cfg.cutoff) * emask[:, None]
        sh = G.spherical_harmonics_dirs(dirs, cfg.l_max)[:, keep_idx]
        return emask, rbf, sh

    def block(x, lp):
        xp = jnp.concatenate([x, jnp.zeros((1, n_lm, d), x.dtype)])
        w_src = lp["w_src"][l_of]
        if cfg.transform_then_gather:
            # H2.1: node-side per-l mixing (linear => commutes with gather)
            yp = jnp.einsum("nlc,lcd->nld", xp, w_src).astype(cfg.act_dtype)
            # H2.2: node-side score features — the edge passes then gather
            # [N, H] instead of the full [N, n_lm, d] irreps for scoring
            a_src = xp[:, 0, :] @ lp["w_att_src"]          # [N+1, H]
            a_dst = xp[:, 0, :] @ lp["w_att_dst"]
        else:
            yp = a_src = a_dst = None

        def chunk_score(rows, cols, emask, rbf):
            if cfg.transform_then_gather:
                score = a_src[rows] + a_dst[cols] + rbf @ lp["w_att_rbf"]
            else:
                s0_src, s0_dst = xp[rows][:, 0, :], xp[cols][:, 0, :]
                score = (
                    s0_src @ lp["w_att_src"] + s0_dst @ lp["w_att_dst"]
                    + rbf @ lp["w_att_rbf"]
                )
            return jnp.where(emask[:, None], score, -1e30)

        # pass 1: segment-softmax stats (max) over incoming edges, chunked
        @jax.checkpoint
        def p1(smax, inp):
            rows, cols = inp
            emask, rbf, _ = edge_geometry(rows, cols)
            score = chunk_score(rows, cols, emask, rbf)
            return smax.at[cols].max(score), None

        smax0 = jnp.full((n + 1, H), -1e30, jnp.float32)
        smax, _ = jax.lax.scan(
            p1, smax0, (row_c, col_c),
            unroll=n_chunks if cfg.probe_unroll else 1,
        )
        smax = jnp.maximum(smax, -1e30)

        # pass 2: unnormalized aggregate + denominators, chunked + remat'd
        @jax.checkpoint
        def p2(carry, inp):
            den, agg = carry
            rows, cols = inp
            emask, rbf, sh = edge_geometry(rows, cols)
            score = chunk_score(rows, cols, emask, rbf)
            p = jnp.exp(score - smax[cols]) * emask[:, None]   # [ec, H]
            den = den.at[cols].add(p)
            rad = (rbf @ lp["w_rad"]).reshape(-1, cfg.l_max + 1, d)[:, l_of, :]
            if cfg.transform_then_gather:
                msg = yp[rows].astype(jnp.float32)             # [ec, n_lm, d]
            else:
                msg = jnp.einsum("elc,lcd->eld", xp[rows], w_src)
            msg = msg * sh[:, :, None] * rad
            msg = msg.reshape(-1, n_lm, H, d // H) * p[:, None, :, None]
            agg = agg.at[cols].add(msg.reshape(-1, n_lm * d))
            return (den, agg), None

        den0 = shard_hint(jnp.full((n + 1, H), 1e-9, jnp.float32), "fsdp", None)
        agg0 = shard_hint(
            jnp.zeros((n + 1, n_lm * d), jnp.float32), "fsdp", None
        )
        (den, agg), _ = jax.lax.scan(
            p2, (den0, agg0), (row_c, col_c),
            unroll=n_chunks if cfg.probe_unroll else 1,
        )
        alpha_den = jnp.repeat(den[:n], d // H, axis=1)        # [n, d]
        agg = (agg[:n].reshape(n, n_lm, d)
               / alpha_den[:, None, :]).astype(x.dtype)
        w_msg = lp["w_msg"][l_of]
        upd = jnp.einsum("nlc,lcd->nld", agg, w_msg)
        # gated nonlinearity: scalars gate everything
        s = G.layer_norm(upd[:, 0, :], lp["ln_g"], lp["ln_b"])
        gate = jax.nn.sigmoid(s @ lp["w_gate"]).reshape(n, cfg.l_max + 1, d)
        upd = upd * gate[:, l_of, :]
        return shard_hint(x + upd, "fsdp", None, None), None

    x = shard_hint(x, "fsdp", None, None)
    x, _ = jax.lax.scan(
        block, x, params["layers"],
        unroll=cfg.n_layers if cfg.probe_unroll else 1,
    )
    per_node = jax.nn.silu(x[:, 0, :] @ params["head_w1"]) @ params["head_w2"]
    energies = G.scatter_sum(per_node, batch["batch_id"], batch["n_graphs"])
    return energies[:, 0]


def loss_fn(params, batch, cfg: EquiformerV2Config) -> jax.Array:
    e = forward(params, batch, cfg)
    return jnp.mean((e - batch["energy"]) ** 2)
