"""GatedGCN (Bresson & Laurent; benchmark config of Dwivedi et al.
[arXiv:2003.00982]): edge-gated message passing with residuals + LayerNorm.

    e'_uv = E1 h_u + E2 h_v + E3 e_uv
    h'_v  = h_v + ReLU(LN( U h_v + Σ_u σ(e'_uv) ⊙ (V h_u) / (Σ σ + ε) ))
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ParamSpec
from repro.models.gnn import common as G


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_hidden: int = 70
    d_feat: int = 1433
    n_classes: int = 40
    dtype: Any = jnp.float32
    probe_unroll: bool = False


def param_specs(cfg: GatedGCNConfig, fsdp=("data",)) -> Dict[str, Any]:
    L, d = cfg.n_layers, cfg.d_hidden
    S = ParamSpec
    return {
        "embed_w": S((cfg.d_feat, d), cfg.dtype, P(None, "model")),
        "embed_b": S((d,), cfg.dtype, P(None), init="zeros"),
        "edge_embed": S((1, d), cfg.dtype, P(None, None)),
        "layers": {
            k: S((L, d, d), cfg.dtype, P(None, None, "model"))
            for k in ("U", "V", "E1", "E2", "E3")
        } | {
            "ln_h_g": S((L, d), cfg.dtype, P(None, None), init="ones"),
            "ln_h_b": S((L, d), cfg.dtype, P(None, None), init="zeros"),
            "ln_e_g": S((L, d), cfg.dtype, P(None, None), init="ones"),
            "ln_e_b": S((L, d), cfg.dtype, P(None, None), init="zeros"),
        },
        "out_w": S((d, cfg.n_classes), cfg.dtype, P("model", None)),
        "out_b": S((cfg.n_classes,), cfg.dtype, P(None), init="zeros"),
    }


def forward(params, batch, cfg: GatedGCNConfig) -> jax.Array:
    """batch: node_feat [N, F], row/col [E] (sentinel N for padding)."""
    n = batch["node_feat"].shape[0]
    row, col = batch["row"], batch["col"]
    emask = row < n
    h = batch["node_feat"].astype(cfg.dtype) @ params["embed_w"] + params["embed_b"]
    e = jnp.broadcast_to(params["edge_embed"], (row.shape[0], cfg.d_hidden))
    hp = jnp.concatenate([h, jnp.zeros((1, cfg.d_hidden), h.dtype)])

    def block(carry, lp):
        h, e = carry
        hp = jnp.concatenate([h, jnp.zeros((1, h.shape[1]), h.dtype)])
        hu, hv = hp[row], hp[col]
        e_new = hu @ lp["E1"] + hv @ lp["E2"] + e @ lp["E3"]
        e_new = G.layer_norm(e_new, lp["ln_e_g"], lp["ln_e_b"])
        gate = jax.nn.sigmoid(e_new) * emask[:, None]
        msg = gate * (hu @ lp["V"])
        agg = G.scatter_sum(msg, col, n)
        den = G.scatter_sum(gate, col, n) + 1e-6
        upd = h @ lp["U"] + agg / den
        upd = G.layer_norm(upd, lp["ln_h_g"], lp["ln_h_b"])
        h = h + jax.nn.relu(upd)
        e = e + jax.nn.relu(e_new)
        return (h, e), None

    (h, e), _ = jax.lax.scan(
        block, (h, e), params["layers"],
        unroll=cfg.n_layers if cfg.probe_unroll else 1,
    )
    return h @ params["out_w"] + params["out_b"]


def loss_fn(params, batch, cfg: GatedGCNConfig) -> jax.Array:
    logits = forward(params, batch, cfg)
    return G.node_xent_loss(logits, batch["labels"], batch["label_mask"])
