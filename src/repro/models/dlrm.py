"""DLRM (Naumov et al. [arXiv:1906.00091]) — MLPerf benchmark config.

  dense features → bottom MLP ┐
                              ├ dot-interaction → top MLP → CTR logit
  26 sparse features → E-bags ┘

JAX has no nn.EmbeddingBag: lookups are ``jnp.take`` + (for multi-hot bags)
``segment_sum`` — implemented here and accelerated by the `embedding_bag`
Pallas kernel on TPU.  Tables are row-sharded over the `model` axis (the
classic hybrid-parallel DLRM schedule: data-parallel MLPs, model-parallel
embeddings; GSPMD materializes the index/vector all_to_all).

The `retrieval_cand` shape scores one query against 10⁶ candidates as a
single batched matmul — no loops.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ParamSpec


# MLPerf DLRM (Criteo 1TB) per-feature vocabulary sizes.
MLPERF_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-mlperf"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 128
    bot_mlp: Tuple[int, ...] = (13, 512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    vocabs: Tuple[int, ...] = MLPERF_VOCABS
    interaction: str = "dot"
    dtype: Any = jnp.float32

    @property
    def n_interactions(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2

    @property
    def top_in(self) -> int:
        return self.embed_dim + self.n_interactions


def param_specs(cfg: DLRMConfig, fsdp=("data",)) -> Dict[str, Any]:
    S = ParamSpec
    specs: Dict[str, Any] = {"tables": {}}
    for i, v in enumerate(cfg.vocabs):
        # row-shard big tables over every mesh axis (10⁸-row tables exceed
        # one chip's HBM even model-sharded); tiny tables replicate.
        # Rows pad to a shardable multiple (extra rows are never indexed).
        if v >= 4096:
            pspec = P(("model",) + tuple(fsdp), None)
            v = ((v + 511) // 512) * 512
        else:
            pspec = P(None, None)
        specs["tables"][f"t{i}"] = S((v, cfg.embed_dim), cfg.dtype, pspec,
                                     scale=1.0 / cfg.embed_dim)
    for j, (a, b) in enumerate(zip(cfg.bot_mlp[:-1], cfg.bot_mlp[1:])):
        specs[f"bot_w{j}"] = S((a, b), cfg.dtype, P(None, None))
        specs[f"bot_b{j}"] = S((b,), cfg.dtype, P(None), init="zeros")
    # top_mlp entries are all layer widths; input = bottom-out ++ interactions
    dims = (cfg.top_in,) + cfg.top_mlp
    for j, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        specs[f"top_w{j}"] = S((a, b), cfg.dtype, P(None, None))
        specs[f"top_b{j}"] = S((b,), cfg.dtype, P(None), init="zeros")
    return specs


def embedding_bag(table: jax.Array, idx: jax.Array) -> jax.Array:
    """Single-hot bag == gather; [B] → [B, dim].  (Multi-hot variant:
    gather + segment_sum — see kernels/embedding_bag for the fused form.)"""
    return jnp.take(table, idx, axis=0)


def _mlp(params, prefix, x, n):
    for j in range(n):
        x = x @ params[f"{prefix}_w{j}"] + params[f"{prefix}_b{j}"]
        if j < n - 1:
            x = jax.nn.relu(x)
    return x


def forward(params, batch, cfg: DLRMConfig) -> jax.Array:
    """batch: dense [B, 13] f32, sparse [B, 26] int32 → logits [B]."""
    dense, sparse = batch["dense"], batch["sparse"]
    B = dense.shape[0]
    d = _mlp(params, "bot", dense.astype(cfg.dtype), len(cfg.bot_mlp) - 1)
    d = jax.nn.relu(d)                                    # [B, dim]
    embs = [
        embedding_bag(params["tables"][f"t{i}"], sparse[:, i])
        for i in range(cfg.n_sparse)
    ]
    feats = jnp.stack([d] + embs, axis=1)                 # [B, F, dim]
    z = jnp.einsum("bfd,bgd->bfg", feats, feats)          # dot interaction
    iu = jnp.triu_indices(feats.shape[1], k=1)
    inter = z[:, iu[0], iu[1]]                            # [B, F(F-1)/2]
    top_in = jnp.concatenate([d, inter], axis=-1)
    logit = _mlp(params, "top", top_in, len(cfg.top_mlp))
    return logit[:, 0]


def loss_fn(params, batch, cfg: DLRMConfig) -> jax.Array:
    logit = forward(params, batch, cfg)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )


def serve_step(params, batch, cfg: DLRMConfig) -> jax.Array:
    return jax.nn.sigmoid(forward(params, batch, cfg))


def retrieval_step(params, batch, cfg: DLRMConfig) -> jax.Array:
    """Score 1 query against n_candidates: candidate item embeddings come
    from table 0 rows (the big item table); one batched matvec."""
    q_dense = batch["dense"]                      # [1, 13]
    d = _mlp(params, "bot", q_dense.astype(cfg.dtype), len(cfg.bot_mlp) - 1)
    d = jax.nn.relu(d)                            # [1, dim]
    cand = embedding_bag(params["tables"]["t0"], batch["candidates"][0])
    scores = (cand @ d[0]) / jnp.sqrt(jnp.float32(cfg.embed_dim))
    return scores                                  # [n_candidates]
