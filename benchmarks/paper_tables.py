"""Benchmarks — one per paper table/figure, at laptop scale.

| bench                      | paper artifact                  |
|----------------------------|---------------------------------|
| reduction_impact           | Fig 7.1 / Table C.2             |
| reduction_partitioned      | Table C.3 (partitioning variant)|
| solver_quality             | Table 7.1                       |
| weak_scaling               | Table 7.2 / C.4 / Fig 7.3       |
| kernel_micro               | (framework) Pallas-kernel refs  |

Each function yields CSV rows: name,us_per_call,derived
(derived = the table's own metric: |V'|/|V|, ω/ω_best, edges/s, ...).
"""

from __future__ import annotations

import time
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _timed(fn, *args, reps: int = 1):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return out, (time.perf_counter() - t0) / reps * 1e6


def bench_reduction_impact() -> Iterator[Row]:
    """Fig 7.1 / Table C.2: kernel size + reduce time vs p, sync vs async."""
    from repro.core import distributed as D, partition as part
    from repro.graphs import generators as gen

    g = gen.rgg2d(4000, avg_deg=8, seed=0)
    for mode in ("sync", "async"):
        for p in (1, 4, 8):
            pg = part.partition_graph(g, p, window_cap=12)
            cfg = D.DisReduConfig(heavy_k=8, mode=mode)

            def run():
                state, prob, rounds = D.disredu(pg, cfg)
                return state

            t0 = time.perf_counter()
            state = run()  # includes compile on first variant
            t0 = time.perf_counter()
            state = run()
            us = (time.perf_counter() - t0) * 1e6
            nv, ne = D.kernel_stats(pg, state)
            name = "DisRedu" + ("S" if mode == "sync" else "A")
            yield (
                f"reduction_impact/{name}/p{p}", us,
                f"V'/V={nv / g.n:.4f};E'/E={ne / g.m:.4f}",
            )


def bench_reduction_partitioned() -> Iterator[Row]:
    """Table C.3: locality-aware order (partitioning stand-in) vs natural."""
    from repro.core import distributed as D, partition as part
    from repro.graphs import generators as gen
    from repro.graphs.relabel import cut_edges_fraction, relabel_bfs

    g = gen.rgg2d(4000, avg_deg=8, seed=1)
    for label, graph in (("natural", g), ("bfs", relabel_bfs(g))):
        pg = part.partition_graph(graph, 8, window_cap=12)
        t0 = time.perf_counter()
        state, prob, _ = D.disredu(pg, D.DisReduConfig(heavy_k=8))
        us = (time.perf_counter() - t0) * 1e6
        nv, _ = D.kernel_stats(pg, state)
        cut = cut_edges_fraction(graph, 8)
        yield (
            f"reduction_partitioned/{label}/p8", us,
            f"V'/V={nv / graph.n:.4f};cut={cut:.3f}",
        )


def bench_solver_quality() -> Iterator[Row]:
    """Table 7.1: quality vs best-found + runtime, all six solvers + seq."""
    from repro.core import distributed as D, partition as part, solvers as S
    from repro.core import sequential as seq
    from repro.graphs import generators as gen

    g = gen.rgg2d(3000, avg_deg=8, seed=2)
    results = {}
    t0 = time.perf_counter()
    w_htwis, _ = seq.solve_reduce_and_peel(g)
    t_htwis = (time.perf_counter() - t0) * 1e6
    results["HtWIS-seq"] = (w_htwis, t_htwis)
    for algo, tag in (("greedy", "G"), ("rg", "RG"), ("rnp", "RnP")):
        for mode, sfx in (("sync", "S"), ("async", "A")):
            pg = part.partition_graph(g, 4, window_cap=12)
            cfg = D.DisReduConfig(heavy_k=8, mode=mode)
            S.solve(pg, algo, cfg)  # compile
            t0 = time.perf_counter()
            members, _ = S.solve(pg, algo, cfg)
            us = (time.perf_counter() - t0) * 1e6
            results[f"{tag}{sfx}"] = (g.set_weight(members), us)
    best = max(w for w, _ in results.values())
    for name, (w, us) in results.items():
        yield (
            f"solver_quality/{name}/p4", us,
            f"quality={w / best:.4f}",
        )


def bench_weak_scaling() -> Iterator[Row]:
    """Table 7.2/C.4 + Fig 7.3: per-family kernel size, quality, throughput
    with fixed per-PE size (n/p const)."""
    from repro.core import distributed as D, partition as part, solvers as S
    from repro.graphs import generators as gen

    per_pe = 800
    for fam in ("gnm", "rgg", "rhg"):
        for p in (1, 4, 8):
            g = gen.FAMILIES[fam](per_pe * p, seed=3)
            pg = part.partition_graph(g, p, window_cap=12)
            cfg = D.DisReduConfig(heavy_k=8, mode="async")
            t0 = time.perf_counter()
            state, prob, _ = D.disredu(pg, cfg)
            dt = time.perf_counter() - t0
            nv, _ = D.kernel_stats(pg, state)
            members, _ = S.solve(pg, "rnp", cfg)
            q = g.set_weight(members)
            yield (
                f"weak_scaling/{fam}/p{p}", dt * 1e6,
                f"V'/V={nv / g.n:.4f};rnp_w={q};eps={g.m / max(dt, 1e-9):.0f}",
            )


def bench_kernel_micro() -> Iterator[Row]:
    """Framework kernels: jnp reference timings (CPU) + shapes."""
    from repro.kernels.embedding_bag.ref import embedding_bag_ref
    from repro.kernels.segment_coo.ops import pack_blocks, segment_sum_coo
    from repro.kernels.wedge_intersect.ref import wedge_intersect_ref

    rng = np.random.default_rng(0)
    # segment_coo
    n, e, d = 5000, 40000, 128
    row = rng.integers(0, n, size=e).astype(np.int32)
    data = jnp.asarray(rng.normal(size=(e, d)), jnp.float32)
    perm, lrow, _ = pack_blocks(row, n, r_blk=8)
    fn = jax.jit(lambda dt: segment_sum_coo(
        dt, jnp.asarray(perm), jnp.asarray(lrow), n, r_blk=8,
        force_pallas=False,
    ))
    _, us = _timed(fn, data, reps=5)
    yield ("kernel/segment_coo/e40k_d128", us, f"gbps={e * d * 8 / us / 1e3:.2f}")

    # wedge_intersect
    E, D = 20000, 16
    wu = jnp.asarray(rng.integers(0, 999, size=(E, D)), jnp.int32)
    awu = jnp.asarray(rng.integers(0, 200, size=(E, D)), jnp.int32)
    actu = jnp.asarray(rng.integers(0, 2, size=(E, D)), jnp.int32)
    fn = jax.jit(lambda a, b, c, dd: wedge_intersect_ref(a, b, c, dd))
    _, us = _timed(fn, wu, wu, awu, actu, reps=5)
    yield ("kernel/wedge_intersect/e20k_d16", us,
           f"medges_s={E / us:.2f}")

    # embedding_bag
    V, B, K, dim = 100_000, 8192, 4, 128
    table = jnp.asarray(rng.normal(size=(V, dim)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, V, size=(B, K)), jnp.int32)
    wgt = jnp.asarray(rng.normal(size=(B, K)), jnp.float32)
    fn = jax.jit(embedding_bag_ref)
    _, us = _timed(fn, table, idx, wgt, reps=5)
    yield ("kernel/embedding_bag/b8192_k4_d128", us,
           f"mlookups_s={B * K / us:.2f}")





def bench_kernel_compaction() -> Iterator[Row]:
    """Beyond-paper §Perf H3.4: adaptive shape descent between reduce
    stages (static-shape analogue of the paper's dependency checking)."""
    import time as _t

    from repro.core import distributed as D, partition as part, solvers as S
    from repro.graphs import generators as gen

    g = gen.rgg2d(6000, avg_deg=8, seed=3)
    cfg = D.DisReduConfig(mode="async", heavy_k=8)
    dcfg = D.DisReduConfig(mode="async", heavy_k=8, descent=True,
                           descent_every=2)
    S.solve(part.partition_graph(g, 8, window_cap=16), "rnp", cfg)  # warm
    t0 = _t.perf_counter()
    m1, _ = S.solve(part.partition_graph(g, 8, window_cap=16), "rnp", cfg)
    t_plain = _t.perf_counter() - t0
    S.solve_staged(g, 8, "rnp", dcfg)  # warm
    t0 = _t.perf_counter()
    m2, st = S.solve_staged(g, 8, "rnp", dcfg)
    t_comp = _t.perf_counter() - t0
    w1, w2 = g.set_weight(m1), g.set_weight(m2)
    yield ("compaction/plain_rnp/p8", t_plain * 1e6, f"w={w1}")
    yield (
        "compaction/descent_rnp/p8", t_comp * 1e6,
        f"w={w2};speedup={t_plain / max(t_comp, 1e-9):.2f}x;"
        f"descents={st['descents']};kernel={st['kernel_ratio']:.3f}",
    )


ALL = (
    bench_reduction_impact,
    bench_reduction_partitioned,
    bench_solver_quality,
    bench_weak_scaling,
    bench_kernel_micro,
    bench_kernel_compaction,
)
