# One function per paper table. Print ``name,us_per_call,derived`` CSV.
# ``--engine-only`` (or the default full run) also times one reduction
# sweep per aggregate backend and writes BENCH_engine.json.
# ``--serve`` runs the batched-serving throughput bench (BENCH_serve.json);
# see benchmarks/compare.py for the CI bench-regression gate.
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _engine_bench(out_path: str, small: bool = False) -> None:
    from benchmarks.engine_bench import run_engine_bench

    try:
        from tests import seed_oracle
    except ImportError:
        seed_oracle = None
    payload = run_engine_bench(out_path, seed_oracle=seed_oracle,
                               small=small)
    for row in payload["results"]:
        for backend, us in row["per_sweep_us"].items():
            print(f"engine_sweep/{row['graph']}/{backend},{us:.1f},"
                  f"schedule={row['schedule']}", flush=True)
        for kind in ("greedy_round_us", "rnp_round_us"):
            for backend, us in row.get(kind, {}).items():
                print(f"engine_{kind[:-3]}/{row['graph']}/{backend},"
                      f"{us:.1f},schedule={row['schedule']}", flush=True)
    print(f"# wrote {out_path}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine-only", action="store_true",
                    help="only the aggregate-engine sweep bench + "
                         "BENCH_engine.json")
    ap.add_argument("--engine-small", action="store_true",
                    help="CI-sized engine bench: one small cell, jnp + "
                         "blocked + pallas-interpret, few reps")
    ap.add_argument("--skip-engine", action="store_true",
                    help="paper tables only, no BENCH_engine.json")
    ap.add_argument("--engine-out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_engine.json"))
    ap.add_argument("--serve", action="store_true",
                    help="batched-serving throughput bench -> "
                         "BENCH_serve.json (with --engine-small: CI-sized)")
    ap.add_argument("--serve-out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serve.json"))
    args = ap.parse_args()

    if args.serve:
        from benchmarks.serve_bench import run_serve_bench

        run_serve_bench(args.serve_out, small=args.engine_small)
        print(f"# wrote {args.serve_out}", flush=True)
        return

    print("name,us_per_call,derived")
    if not args.engine_only:
        from benchmarks import paper_tables

        for bench in paper_tables.ALL:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}", flush=True)
    if not args.skip_engine:
        _engine_bench(args.engine_out, small=args.engine_small)


if __name__ == "__main__":
    main()
