# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    from benchmarks import paper_tables

    print("name,us_per_call,derived")
    for bench in paper_tables.ALL:
        for name, us, derived in bench():
            print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
