"""Sustained-throughput benchmark for the batched MWIS serving layer.

Measures instances/sec and p50/p99 per-batch latency for each
(serve cell × backend × batch size) program of :mod:`repro.core.serve`,
in the steady serving state (all programs compiled, all topologies
cached, fresh weights per request).  Writes ``BENCH_serve.json``.

Full mode covers every serve cell at two batch sizes on the jnp backend
plus blocked and pallas-interpret on the smallest cell (the interpret
rows are CPU-simulation numbers, not TPU projections).  ``small=True``
is the CI shape: smallest cell only, jnp + blocked, few requests.

Every row carries the per-stage breakdown (pack / H2D transfer / solve /
fetch ms and the pipeline overlap ratio) from ``MWISService.stats``.
Batch-4 rows get an ``instances_per_sec_pipelined`` column driven with
multi-chunk calls (4 chunks per ``solve_batch``) so the overlapped host
pipeline actually engages.  A ``devices=N`` multi-device section shards
the batch axis over a ``serve`` mesh — when fewer devices are visible
than requested the rows run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (CPU emulation:
correctness + overlap surface, not real accelerator speedup).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MULTIDEVICE_N = 4


def _stage_cols(svc) -> dict:
    """Per-stage timing columns of a driven service (cumulative)."""
    s = svc.stats
    return dict(
        devices=s["devices"],
        stage_ms=s["stage_ms"],
        stage_p50_ms=s["stage_p50_ms"],
        overlap_ratio=s["overlap_ratio"],
        chunks=s["chunks"],
        pipelined_chunks=s["pipelined_chunks"],
    )


def _instance_stream(cell, n_topologies: int, repeats: int, seed: int):
    """Request list for one cell: n_topologies graphs sized to ~80% of the
    cell, each repeated with fresh weights (the re-auction pattern)."""
    import numpy as np

    from repro.graphs.generators import gnm

    rng = np.random.default_rng(seed)
    reqs = []
    for t in range(n_topologies):
        n = max(8, int(cell.L * 0.8))
        m = min(2 * n, cell.E // 4)
        g = gnm(n, m, seed=seed + t)
        for _ in range(repeats):
            w = rng.integers(1, 201, size=g.n).astype(np.int32)
            reqs.append(type(g)(indptr=g.indptr, indices=g.indices,
                                weights=w))
    return reqs


def _multidevice_rows(small: bool, devices: int) -> list:
    """Benchmark rows with the batch axis sharded over ``devices``.

    Must run in a process where ``jax.device_count() >= devices`` —
    either real accelerators or CPU host devices forced via XLA_FLAGS.
    Calls carry 4 chunks of ``batch`` requests so pipelining engages.
    """
    from repro.core import serve as SV

    cells = SV.serve_cells()
    if small:
        plan = [(cells[0], 4, "jnp")]
        n_chunks = 2
    else:
        plan = [(c, 4, "jnp") for c in cells]
        plan += [(cells[min(1, len(cells) - 1)], 16, "jnp")]
        n_chunks = 4
    rows = []
    for cell, batch, backend in plan:
        svc = SV.MWISService(
            SV.ServeConfig(algo="rg", backend=backend, max_batch=batch,
                           devices=devices)
        )
        reqs = _instance_stream(cell, n_chunks, batch, seed=17)
        stats = SV.measure_throughput(svc, [reqs], warmup=1)
        rows.append(dict(
            cell=cell.name, backend=backend, batch=batch,
            L=cell.L, E=cell.E,
            instances_per_sec=stats["instances_per_sec"],
            p50_ms=stats["p50_ms"], p99_ms=stats["p99_ms"],
            instances=stats["instances"],
            **_stage_cols(svc),
        ))
    return rows


def _multidevice_section(small: bool, devices: int = MULTIDEVICE_N) -> list:
    """Multi-device rows, in-process when enough devices are visible,
    else via a subprocess with forced CPU host devices.  Returns [] (with
    a warning) if the subprocess fails — the rest of the bench stands."""
    import jax

    if jax.device_count() >= devices:
        return _multidevice_rows(small, devices)
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       ".serve_md_rows.json")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={devices}"
                        ).strip()
    cmd = [sys.executable, os.path.abspath(__file__),
           "--multidevice-child", out, str(devices)]
    if small:
        cmd.append("--small")
    try:
        subprocess.run(cmd, env=env, check=True, timeout=3600)
        with open(out) as f:
            rows = json.load(f)
        os.remove(out)
        return rows
    except Exception as e:  # noqa: BLE001 — bench degrades, not dies
        print(f"# multidevice section skipped: {e}", flush=True)
        return []


def run_serve_bench(out_path: str, small: bool = False) -> dict:
    import jax

    from repro.core import serve as SV

    cells = SV.serve_cells()
    if small:
        plan = [(cells[0], b, bk)
                for b in (1, 4) for bk in ("jnp", "blocked")]
        n_topologies, repeats = 2, 2
    else:
        plan = [(c, b, "jnp") for c in cells for b in (4, 16)]
        plan += [(cells[0], 4, "blocked"), (cells[0], 4, "pallas")]
        n_topologies, repeats = 4, 4

    results = []
    for cell, batch, backend in plan:
        svc = SV.MWISService(
            SV.ServeConfig(algo="rg", backend=backend, max_batch=batch)
        )
        reqs = _instance_stream(cell, n_topologies, repeats, seed=17)
        batches = [reqs[i:i + batch] for i in range(0, len(reqs), batch)]
        stats = SV.measure_throughput(svc, batches, warmup=1)
        # second pass with verify=full on the same topology cache: the
        # delta is the pure post-solve audit cost (independence check +
        # weight recomputation per request)
        svc_v = SV.MWISService(
            SV.ServeConfig(algo="rg", backend=backend, max_batch=batch,
                           verify="full")
        )
        stats_v = SV.measure_throughput(svc_v, batches, warmup=1)
        ips, ips_v = stats["instances_per_sec"], stats_v["instances_per_sec"]
        overhead = round(100.0 * (ips - ips_v) / ips, 1) if ips else 0.0
        label = "pallas-interpret" if backend == "pallas" else backend
        row = dict(
            cell=cell.name, backend=label, batch=batch,
            L=cell.L, E=cell.E,
            instances_per_sec=ips,
            instances_per_sec_verify_full=ips_v,
            verify_full_overhead_pct=overhead,
            p50_ms=stats["p50_ms"], p99_ms=stats["p99_ms"],
            instances=stats["instances"],
            cache=svc.stats,
            **_stage_cols(svc),
        )
        if batch >= 4 and backend == "jnp":
            # multi-chunk calls (4 x batch requests, max_batch=batch) so
            # chunk k+1's host pack/H2D hides under chunk k's solve
            svc_p = SV.MWISService(
                SV.ServeConfig(algo="rg", backend=backend, max_batch=batch)
            )
            reqs_p = _instance_stream(cell, 4, batch, seed=17)
            stats_p = SV.measure_throughput(svc_p, [reqs_p], warmup=1)
            row["instances_per_sec_pipelined"] = \
                stats_p["instances_per_sec"]
            row["overlap_ratio_pipelined"] = \
                svc_p.stats["overlap_ratio"]
        results.append(row)
        print(f"serve/{cell.name}/{label}/b{batch},"
              f"{ips},"
              f"p50={stats['p50_ms']}ms p99={stats['p99_ms']}ms "
              f"verify_full={ips_v} ({overhead}% overhead)"
              + (f" pipelined={row['instances_per_sec_pipelined']}"
                 f" overlap={row['overlap_ratio_pipelined']}"
                 if "instances_per_sec_pipelined" in row else ""),
              flush=True)

    # ---- shape-descent rows: biggest cell, fixed vs descent="auto" ---- #
    # (the staged path solves per-instance, so this also measures the
    # descent overhead against the batched fixed-shape program)
    descent_rows = []
    d_cell = cells[-1]
    d_plan = [("jnp", 1)] if small else [("jnp", 1), ("blocked", 1)]
    nt, rp = (1, 2) if small else (2, 3)
    for backend, batch in d_plan:
        reqs = _instance_stream(d_cell, nt, rp, seed=23)
        batches = [reqs[i:i + batch] for i in range(0, len(reqs), batch)]
        svc_off = SV.MWISService(
            SV.ServeConfig(algo="rg", backend=backend, max_batch=batch))
        svc_on = SV.MWISService(
            SV.ServeConfig(algo="rg", backend=backend, max_batch=batch,
                           descent="auto", descent_min_L=d_cell.L))
        stats_off = SV.measure_throughput(svc_off, batches, warmup=1)
        stats_on = SV.measure_throughput(svc_on, batches, warmup=1)
        s = svc_on.stats
        row = dict(
            cell=d_cell.name, backend=backend, batch=batch,
            instances_per_sec_fixed=stats_off["instances_per_sec"],
            instances_per_sec_descent=stats_on["instances_per_sec"],
            p50_ms_fixed=stats_off["p50_ms"],
            p50_ms_descent=stats_on["p50_ms"],
            descent_solves=s["descent_solves"], descents=s["descents"],
            oversize_admitted=s["oversize_admitted"],
            cache_descent_hits=s["cache_descent_hits"],
            cache_descent_misses=s["cache_descent_misses"],
        )
        descent_rows.append(row)
        print(f"serve-descent/{d_cell.name}/{backend}/b{batch},"
              f"fixed={row['instances_per_sec_fixed']} "
              f"descent={row['instances_per_sec_descent']} inst/s "
              f"(descents={row['descents']})", flush=True)

    # ---- multi-device rows: batch axis sharded over a serve mesh ------ #
    md_rows = _multidevice_section(small)
    for row in md_rows:
        print(f"serve-md/{row['cell']}/{row['backend']}"
              f"/b{row['batch']}/d{row['devices']},"
              f"{row['instances_per_sec']},"
              f"overlap={row['overlap_ratio']} "
              f"stage_p50={row['stage_p50_ms']}", flush=True)

    payload = dict(
        meta=dict(
            unit="sustained instances/sec + per-batch latency ms, steady "
                 "state (programs compiled, topologies cached, fresh "
                 "weights per request)",
            jax=jax.__version__,
            device=jax.default_backend(),
            small=small,
            note="pallas-interpret rows run the kernel in CPU interpret "
                 "mode — correctness surface, not TPU performance",
            verify_note="instances_per_sec_verify_full re-runs the same "
                        "stream with ServeConfig.verify='full' (post-solve "
                        "independence + weight audit on every request)",
            descent_note="descent rows compare the batched fixed-shape "
                         "program against the per-instance shape-descent "
                         "path (ServeConfig.descent='auto') on the "
                         "biggest serve cell",
            multidevice_note=f"multidevice rows shard the batch axis over "
                             f"a {MULTIDEVICE_N}-device serve mesh, driven "
                             f"with multi-chunk calls so the host pipeline "
                             f"engages; on CPU they run in a subprocess "
                             f"with forced host devices (correctness + "
                             f"overlap surface, not accelerator speedup)",
        ),
        results=results,
        descent=descent_rows,
        multidevice=md_rows,
    )
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    return payload


if __name__ == "__main__":
    small = "--small" in sys.argv
    if "--multidevice-child" in sys.argv:
        # child mode: XLA_FLAGS is already in the environment (set by the
        # parent BEFORE this process imports jax) — write rows and exit
        i = sys.argv.index("--multidevice-child")
        child_out, devices = sys.argv[i + 1], int(sys.argv[i + 2])
        rows = _multidevice_rows(small, devices)
        with open(child_out, "w") as f:
            json.dump(rows, f)
        sys.exit(0)
    out = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
    run_serve_bench(out, small=small)
    print(f"# wrote {out}")
