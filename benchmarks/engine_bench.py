"""Aggregate-engine benchmark: sweep + solver-round timings per backend →
BENCH_engine.json.

Times, on the paper's generator families:

  * ONE reduction sweep (the engine's unit of work: aggregate computation
    + all scheduled rule families) under

      - the seed-semantics reference (frozen oracle, fused sweep, jnp ops),
      - the engine jnp backend        (op-identical to the seed — the
                                       no-regression check),
      - the engine blocked backend at every R_BLK candidate (blocked-ELL
        layout, jnp block kernels); ``blocked`` is the fixed R_BLK=8
        baseline and ``blocked-auto`` the measured best over the candidate
        table — the plan-build-time autotune record,
      - the engine pallas backend     (fused multi-payload kernel; interpret
        mode off TPU, so only a small instance — interpret timings measure
        correctness plumbing, not TPU performance);

  * ONE greedy round (weighted-Luby step + halo exchange) and ONE RnP round
    (rule sweep + exchange + peel) per backend — the solver hot loops that
    re-enter reduction many times per run, now routed through the same
    aggregate layer.

Emits BENCH_engine.json so the perf trajectory of the hot path is recorded
per PR.  Run via ``python benchmarks/run.py --engine-only`` (``--engine-
small`` for the CI-sized variant).
"""

from __future__ import annotations

import json
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _time_interleaved(entries, reps: int = 30) -> dict:
    """entries: {label: (fn, state)} → min-of-reps us, reps interleaved
    across labels so machine noise hits every backend equally."""
    for fn, state in entries.values():
        jax.block_until_ready(fn(state))  # compile
        jax.block_until_ready(fn(state))  # warm
    best = {label: float("inf") for label in entries}
    for _ in range(reps):
        for label, (fn, state) in entries.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(state))
            best[label] = min(best[label], time.perf_counter() - t0)
    return {label: round(us * 1e6, 1) for label, us in best.items()}


def _bench_graph(name, g, p, *, schedule: str, with_pallas: bool,
                 seed_oracle=None, reps: int = 30,
                 candidates: Optional[Tuple[int, ...]] = None) -> dict:
    from repro.core import distributed as D, engine as E, rules as R
    from repro.core import partition as part
    from repro.core import solvers as SOL

    # the fixed R_BLK baseline must always be in the candidate table: it is
    # the "blocked" label and the floor the autotune is judged against
    candidates = tuple(sorted(
        set(candidates or E.R_BLK_CANDIDATES) | {E.R_BLK}
    ))
    row = {"graph": name, "n": g.n, "m": g.m, "p": p, "schedule": schedule}
    pg = part.partition_graph(g, p, window_cap=12)

    probs = {"jnp": D.build_union_problem(pg, "jnp")}
    for c in candidates:
        probs[f"blocked-r{c}"] = D.build_union_problem(pg, "blocked", c)

    def sweep_entry(backend, prob):
        fn = jax.jit(lambda s, _aux=prob.aux, _pl=prob.plan, _b=backend:
                     E.sweep(s, _aux, schedule=schedule, backend=_b,
                             plan=_pl))
        return fn, R.init_state(prob.w0, prob.is_local, prob.is_ghost)

    entries = {"jnp": sweep_entry("jnp", probs["jnp"])}
    cand_label = {}
    for c in candidates:
        # fixed-block baseline keeps its historical label "blocked"
        label = "blocked" if c == E.R_BLK else f"blocked-r{c}"
        cand_label[c] = label
        entries[label] = sweep_entry("blocked", probs[f"blocked-r{c}"])
    if with_pallas:
        label = "pallas-interpret" if jax.default_backend() != "tpu" \
            else "pallas"
        entries[label] = sweep_entry(
            "pallas", probs[f"blocked-r{E.R_BLK}"]
        )
    if seed_oracle is not None:
        prob = probs["jnp"]
        state0 = seed_oracle.init_state(
            prob.w0, prob.is_local, prob.is_ghost
        )
        entries["seed-fused-jnp"] = (
            jax.jit(lambda s, _aux=prob.aux:
                    seed_oracle.sweep_cheap_fused(s, _aux)),
            state0,
        )
    sweep_us = _time_interleaved(entries, reps=reps)
    # measured autotune: best candidate over the table (includes the fixed
    # baseline, so blocked-auto is never slower than blocked by
    # construction); the analytic pick is recorded for comparison
    best_c = min(candidates, key=lambda c: sweep_us[cand_label[c]])
    sweep_us["blocked-auto"] = sweep_us[cand_label[best_c]]
    row["per_sweep_us"] = sweep_us
    row["blocked_auto"] = {
        "r_blk": best_c,
        "analytic_r_blk": E.autotune_r_blk(
            jax.device_get(probs["jnp"].aux.row), pg.p * pg.V, candidates
        ),
    }

    # --- solver rounds per backend ------------------------------------ #
    # the blocked rounds run the autotuned plan and say so in the label
    # (the "blocked" sweep label above is the fixed R_BLK=8 baseline)
    round_backends = [("jnp", probs["jnp"]),
                      ("blocked-auto", probs[f"blocked-r{best_c}"])]
    if with_pallas:
        round_backends.append(
            ("pallas-interpret" if jax.default_backend() != "tpu"
             else "pallas", probs[f"blocked-r{best_c}"])
        )

    greedy_entries, rnp_entries = {}, {}
    for label, prob in round_backends:
        backend = label.split("-")[0]  # blocked-auto / pallas-interpret
        ctx = SOL._union_ctx(prob, backend)
        state0 = R.init_state(prob.w0, prob.is_local, prob.is_ghost)

        def greedy_round(s, _aux=prob.aux, _pl=prob.plan, _b=backend,
                         _ctx=ctx):
            s = SOL.greedy_step(s, _aux, backend=_b, plan=_pl)
            return _ctx.exchange(s)[0]

        def rnp_round(s, _aux=prob.aux, _pl=prob.plan, _b=backend,
                      _ctx=ctx):
            s = E.sweep(s, _aux, schedule=schedule, backend=_b, plan=_pl)
            s = _ctx.exchange(s)[0]
            score = SOL.peel_score(s, _aux, backend=_b, plan=_pl)
            return _ctx.peel(s, score)

        greedy_entries[label] = (jax.jit(greedy_round), state0)
        rnp_entries[label] = (jax.jit(rnp_round), state0)
    row["greedy_round_us"] = _time_interleaved(greedy_entries, reps=reps)
    row["rnp_round_us"] = _time_interleaved(rnp_entries, reps=reps)
    return row


def _bench_descent(small: bool = False) -> dict:
    """Fixed-shape vs shape-descent end-to-end greedy solve on a
    serve_m-sized instance (the ISSUE's target cell), plus the per-round
    alive-vertex/stage-time trajectory of both paths.

    The trajectory rows come from ``solve_staged(..., trajectory=True)``
    with one-round stages — an empty ladder keeps the fixed path at the
    input shape while still reporting per-round alive counts.  The timed
    comparison runs each path monolithically (no per-round readback), and
    asserts the two member masks are bit-identical.
    """
    import numpy as np

    from repro.configs import base as CFG
    from repro.core import distributed as D
    from repro.core import partition as part
    from repro.core import solvers as SOL
    from repro.core.graph import from_edge_list
    from repro.graphs import generators as gen

    cell = CFG.MWIS_SHAPES["serve_m"]
    n = int(cell["L"] * 0.8)
    # bulk + hard kernel: a random bulk that greedy decides in a couple of
    # rounds, plus a weight-ramp path whose greedy frontier advances ~one
    # vertex per round — the motivating serve_m workload (the kernel
    # collapses to a small fraction fast, then the solver grinds on it)
    n_kernel = 200
    n_bulk = n - n_kernel
    bulk = gen.gnm(n_bulk, 3 * n_bulk, seed=11)
    bsrc = bulk.edge_sources()
    und = bsrc < bulk.indices
    pairs = np.stack([bsrc[und], bulk.indices[und]], axis=1).astype(np.int64)
    chain = np.arange(n_bulk, n - 1, dtype=np.int64)
    pairs = np.concatenate(
        [pairs, np.stack([chain, chain + 1], axis=1)], axis=0)
    weights = np.concatenate([
        np.asarray(bulk.weights, np.int64),
        np.arange(1, n_kernel + 1, dtype=np.int64),   # the ramp
    ]).astype(np.int32)
    g = from_edge_list(n, pairs, weights)
    pad = dict(L=cell["L"], E=cell["E"], G=cell["G"], B=cell["B"],
               S=cell["S"])
    algo, p = "greedy", 1
    pg = part.partition_graph(g, p, window_cap=cell["D"],
                              common_cap=cell["Dc"], pad_to=pad)
    cfg_fixed = D.DisReduConfig(mode="sync", heavy_k=8)
    cfg_desc = D.DisReduConfig(mode="sync", heavy_k=8, descent=True,
                               descent_every=2)
    cfg_traj = D.DisReduConfig(mode="sync", heavy_k=8, descent=True,
                               descent_every=1)

    def run(cfg, **kw):
        return SOL.solve_staged(g, p, algo, cfg, pg=pg, **kw)

    # per-round trajectories (stage = 1 round; empty ladder = never move)
    _, st_tf = run(cfg_traj, ladder=(), trajectory=True)
    _, st_td = run(cfg_traj, trajectory=True)

    # end-to-end timing, warm then min-of-reps (same topology → plan
    # cache + jit caches hot, exactly the serving steady state)
    reps = 2 if small else 4
    m_fixed, _ = run(cfg_fixed)
    m_desc, st_d = run(cfg_desc)
    t_fixed = t_desc = float("inf")
    for _ in range(reps):
        _, st = run(cfg_fixed)
        t_fixed = min(t_fixed, st["t_total"])
        _, st = run(cfg_desc)
        t_desc = min(t_desc, st["t_total"])
    assert (m_fixed == m_desc).all(), \
        "shape descent changed the greedy solution"

    # descent plan reuse: run the blocked-backend descent path twice on one
    # shared PlanCache — the second solve's descent plans must all hit
    from repro.core import engine as E
    cache = E.PlanCache(max_entries=64)
    cfg_blk = D.DisReduConfig(mode="sync", heavy_k=8, backend="blocked",
                              descent=True, descent_every=2)
    m_blk, _ = SOL.solve_staged(g, p, algo, cfg_blk, pg=pg,
                                plan_cache=cache)
    SOL.solve_staged(g, p, algo, cfg_blk, pg=pg, plan_cache=cache)
    assert (m_blk == m_fixed).all(), \
        "blocked-backend descent diverged from jnp"
    cs = cache.stats
    return {
        "graph": f"bulk_ramp_n{n}", "n": g.n, "m": g.m, "p": p,
        "algo": algo, "cell": "serve_m",
        "fixed_us": round(t_fixed * 1e6, 1),
        "descent_us": round(t_desc * 1e6, 1),
        "speedup": round(t_fixed / max(t_desc, 1e-9), 2),
        "descents": st_d["descents"],
        "path": [e["cell"] for e in st_d["path"]],
        "bit_identical": True,
        "plan_cache": {
            "hits": cs.hits, "misses": cs.misses,
            "descent_hits": cs.descent_hits,
            "descent_misses": cs.descent_misses,
        },
        "trajectory_fixed": st_tf["stages"],
        "trajectory_descent": st_td["stages"],
    }


def run_engine_bench(out_path: str = "BENCH_engine.json",
                     seed_oracle=None, small: bool = False) -> dict:
    from repro.graphs import generators as gen

    results = []
    if not small:
        for fam, n in (("gnm", 2000), ("rgg", 2000), ("rhg", 1500)):
            g = gen.FAMILIES[fam](n, seed=7)
            results.append(_bench_graph(
                f"{fam}_n{n}", g, 4, schedule="cheap-fused",
                with_pallas=False, seed_oracle=seed_oracle,
            ))
    # pallas path: interpret mode is orders slower than compiled — bench a
    # small instance only, as a plumbing/latency record (TPU numbers TBD).
    # This is also the whole CI-sized (small=True) run.
    g = gen.FAMILIES["rgg"](300, seed=7)
    results.append(_bench_graph(
        "rgg_n300_small", g, 2, schedule="cheap-fused", with_pallas=True,
        seed_oracle=seed_oracle if small else None,
        reps=5 if small else 30,
        candidates=(8, 16) if small else None,
    ))
    payload = {
        "meta": {
            "unit": "us per reduction sweep (aggregates + all scheduled "
                    "rule families) / per solver round, union path",
            "jax": jax.__version__,
            "device": jax.default_backend(),
            "small": small,
            "note": "engine jnp backend is op-identical to the seed sweep "
                    "(bit-parity: tests/test_engine_parity.py); "
                    "seed-fused-jnp rows time the frozen seed oracle "
                    "directly — the no-regression reference; 'blocked' is "
                    "the fixed R_BLK=8 baseline, 'blocked-auto' the "
                    "measured best over the R_BLK candidate table "
                    "(plan-build-time autotune); greedy_round_us / "
                    "rnp_round_us time one solver round (step + halo "
                    "exchange [+ peel]) per backend, blocked rounds on "
                    "the autotuned plan; 'descent' compares the "
                    "fixed-shape vs shape-descent end-to-end greedy solve "
                    "on a serve_m-sized instance (bit-identical members) "
                    "with per-round alive/time trajectories",
        },
        "results": results,
        "descent": _bench_descent(small=small),
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload
