"""Aggregate-engine benchmark: per-sweep timing per backend → BENCH_engine.json.

Times ONE reduction sweep (the engine's unit of work: aggregate computation
+ all scheduled rule families) on the paper's generator families, under

  * the seed-semantics reference (frozen oracle, fused sweep, jnp ops),
  * the engine jnp backend        (op-identical to the seed — the
                                   no-regression check),
  * the engine blocked backend    (blocked-ELL layout, jnp block kernels),
  * the engine pallas backend     (fused multi-payload kernel; interpret
                                   mode off TPU, so only a small instance —
                                   interpret timings measure correctness
                                   plumbing, not TPU performance).

Emits BENCH_engine.json so the perf trajectory of the hot path is recorded
per PR.  Run via ``python benchmarks/run.py --engine-only``.
"""

from __future__ import annotations

import json
import time
from typing import Optional

import jax
import jax.numpy as jnp


def _time_interleaved(entries, reps: int = 30) -> dict:
    """entries: {label: (fn, state)} → min-of-reps us, reps interleaved
    across labels so machine noise hits every backend equally."""
    for fn, state in entries.values():
        jax.block_until_ready(fn(state))  # compile
        jax.block_until_ready(fn(state))  # warm
    best = {label: float("inf") for label in entries}
    for _ in range(reps):
        for label, (fn, state) in entries.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(state))
            best[label] = min(best[label], time.perf_counter() - t0)
    return {label: round(us * 1e6, 1) for label, us in best.items()}


def _bench_graph(name, g, p, *, schedule: str, with_pallas: bool,
                 seed_oracle=None) -> dict:
    from repro.core import distributed as D, engine as E, rules as R

    from repro.core import partition as part

    row = {"graph": name, "n": g.n, "m": g.m, "p": p, "schedule": schedule}
    pg = part.partition_graph(g, p, window_cap=12)
    entries = {}
    for backend in ("jnp", "blocked") + (("pallas",) if with_pallas else ()):
        prob = D.build_union_problem(pg, backend)
        state0 = R.init_state(prob.w0, prob.is_local, prob.is_ghost)
        fn = jax.jit(lambda s, _aux=prob.aux, _pl=prob.plan, _b=backend:
                     E.sweep(s, _aux, schedule=schedule, backend=_b, plan=_pl))
        label = "pallas-interpret" if (
            backend == "pallas" and jax.default_backend() != "tpu"
        ) else backend
        entries[label] = (fn, state0)
    if seed_oracle is not None:
        prob = D.build_union_problem(pg)
        state0 = seed_oracle.init_state(
            prob.w0, prob.is_local, prob.is_ghost
        )
        entries["seed-fused-jnp"] = (
            jax.jit(lambda s, _aux=prob.aux:
                    seed_oracle.sweep_cheap_fused(s, _aux)),
            state0,
        )
    row["per_sweep_us"] = _time_interleaved(entries)
    return row


def run_engine_bench(out_path: str = "BENCH_engine.json",
                     seed_oracle=None) -> dict:
    from repro.graphs import generators as gen

    results = []
    for fam, n in (("gnm", 2000), ("rgg", 2000), ("rhg", 1500)):
        g = gen.FAMILIES[fam](n, seed=7)
        results.append(_bench_graph(
            f"{fam}_n{n}", g, 4, schedule="cheap-fused",
            with_pallas=False, seed_oracle=seed_oracle,
        ))
    # pallas path: interpret mode is orders slower than compiled — bench a
    # small instance only, as a plumbing/latency record (TPU numbers TBD)
    g = gen.FAMILIES["rgg"](300, seed=7)
    results.append(_bench_graph(
        "rgg_n300_small", g, 2, schedule="cheap-fused", with_pallas=True,
    ))
    payload = {
        "meta": {
            "unit": "us per reduction sweep (aggregates + all scheduled "
                    "rule families), union path",
            "jax": jax.__version__,
            "device": jax.default_backend(),
            "note": "engine jnp backend is op-identical to the seed sweep "
                    "(bit-parity: tests/test_engine_parity.py); "
                    "seed-fused-jnp rows time the frozen seed oracle "
                    "directly — the no-regression reference",
        },
        "results": results,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload
