"""Bench-regression gate: diff a fresh BENCH_engine.json against the
committed baseline and fail CI on real slowdowns.

    python benchmarks/compare.py BENCH_engine.baseline.json \\
        BENCH_engine.json --threshold 1.5 --out BENCH_diff.json

Gating policy:

  * Only the **jnp** and **blocked-auto** labels gate (the portable
    backend and the autotuned blocked plan — the two paths users get by
    default).  ``pallas*`` / interpret rows are warn-only: interpret mode
    is a CPU correctness simulation whose timing is noise.
  * Metrics compared: ``per_sweep_us`` plus the solver-round metrics
    (``greedy_round_us``, ``rnp_round_us``), per graph row.
  * When both files carry the frozen seed oracle reference
    (``per_sweep_us["seed-fused-jnp"]``), each metric is **normalized**
    by its own file's reference before comparing — the ratio
    (fresh/fresh_ref) / (base/base_ref) cancels machine-speed differences
    between the baseline machine and the CI runner.  Without the
    reference the raw fresh/base ratio is used.
  * A gated cell regresses when its ratio exceeds ``--threshold``
    (default 1.5, env ``BENCH_REGRESSION_THRESHOLD``).  Any regression
    → exit 1.  Missing rows/labels in the fresh file warn only (CI small
    mode runs a subset).

Writes the full diff (every compared cell with both values and the
ratio) to ``--out`` for upload as a PR artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

GATED_LABELS = ("jnp", "blocked-auto")
METRICS = ("per_sweep_us", "greedy_round_us", "rnp_round_us")
REF_LABEL = "seed-fused-jnp"
DEFAULT_THRESHOLD = 1.5


def _rows_by_graph(payload: dict) -> dict:
    return {r["graph"]: r for r in payload.get("results", [])}


def _ref(row: dict) -> float | None:
    v = row.get("per_sweep_us", {}).get(REF_LABEL)
    return float(v) if v else None


def compare(baseline: dict, fresh: dict, threshold: float) -> dict:
    base_rows = _rows_by_graph(baseline)
    fresh_rows = _rows_by_graph(fresh)
    cells, regressions, warnings, missing = [], [], [], []

    for graph, brow in sorted(base_rows.items()):
        frow = fresh_rows.get(graph)
        if frow is None:
            missing.append(f"graph {graph!r} absent from fresh results")
            continue
        bref, fref = _ref(brow), _ref(frow)
        normalized = bref is not None and fref is not None
        for metric in METRICS:
            bvals = brow.get(metric, {})
            fvals = frow.get(metric, {})
            for label, bus in sorted(bvals.items()):
                if label == REF_LABEL:
                    continue
                fus = fvals.get(label)
                if fus is None:
                    missing.append(
                        f"{graph}/{metric}/{label} absent from fresh")
                    continue
                bus, fus = float(bus), float(fus)
                if bus <= 0:
                    continue
                if normalized:
                    ratio = (fus / fref) / (bus / bref)
                else:
                    ratio = fus / bus
                gated = label in GATED_LABELS
                regressed = ratio > threshold
                cell = dict(
                    graph=graph, metric=metric, label=label,
                    baseline_us=bus, fresh_us=fus,
                    ratio=round(ratio, 3), normalized=normalized,
                    gated=gated, regressed=regressed,
                )
                cells.append(cell)
                if regressed and gated:
                    regressions.append(cell)
                elif regressed:
                    warnings.append(cell)

    # ---- shape-descent columns (warn-only, never gate) ---------------- #
    descent = None
    descent_warnings: list[str] = []
    bdesc, fdesc = baseline.get("descent"), fresh.get("descent")
    if fdesc:
        pc = fdesc.get("plan_cache") or {}
        d_hits = pc.get("descent_hits", 0)
        d_total = d_hits + pc.get("descent_misses", 0)
        descent = dict(
            graph=fdesc.get("graph"), cell=fdesc.get("cell"),
            baseline_speedup=(bdesc or {}).get("speedup"),
            fresh_speedup=fdesc.get("speedup"),
            fresh_fixed_us=fdesc.get("fixed_us"),
            fresh_descent_us=fdesc.get("descent_us"),
            descents=fdesc.get("descents"),
            bit_identical=fdesc.get("bit_identical"),
            plan_cache_hit_rate=(round(d_hits / d_total, 3)
                                 if d_total else None),
            plan_cache=pc,
            gated=False,
        )
        if fdesc.get("bit_identical") is False:
            descent_warnings.append(
                "descent members differ from fixed-shape path")
        bsp, fsp = (bdesc or {}).get("speedup"), fdesc.get("speedup")
        if bsp and fsp and fsp < bsp / threshold:
            descent_warnings.append(
                f"descent speedup dropped: {bsp} -> {fsp} "
                f"(more than {threshold}x below baseline)")
        if fsp is not None and fsp < 1.0:
            descent_warnings.append(
                f"descent slower than fixed shape (speedup {fsp})")
    elif bdesc:
        missing.append("descent section absent from fresh results")

    return dict(
        threshold=threshold,
        gated_labels=list(GATED_LABELS),
        regressions=regressions,
        warnings=warnings,
        missing=missing,
        cells=cells,
        descent=descent,
        descent_warnings=descent_warnings,
    )


def compare_serve(baseline: dict, fresh: dict, threshold: float) -> dict:
    """Diff two BENCH_serve.json payloads on sustained instances/sec.

    **Warn-only, never gates**: serving throughput on shared CI runners
    is noisier than the normalized engine metrics, and the multidevice
    rows run on forced-host CPU devices whose relative speed says nothing
    about accelerators.  Rows are keyed (cell, backend, batch, devices);
    committed baselines without a ``devices`` column compare as 1.
    """
    def key(r):
        return (r.get("cell"), r.get("backend"), r.get("batch"),
                r.get("devices", 1))

    rows, warnings, missing = [], [], []
    for section, tag in (("results", "serve"), ("multidevice", "serve-md")):
        brows = {key(r): r for r in baseline.get(section) or []}
        frows = {key(r): r for r in fresh.get(section) or []}
        for k, br in sorted(brows.items()):
            fr = frows.get(k)
            b_ips = br.get("instances_per_sec")
            if fr is None:
                missing.append(f"{tag} {k} absent from fresh")
                continue
            f_ips = fr.get("instances_per_sec")
            if not b_ips or f_ips is None:
                continue
            slowdown = (float(b_ips) / float(f_ips) if f_ips
                        else float("inf"))
            row = dict(
                section=tag, cell=k[0], backend=k[1], batch=k[2],
                devices=k[3],
                baseline_ips=b_ips, fresh_ips=f_ips,
                slowdown=round(slowdown, 3),
                overlap_ratio=fr.get("overlap_ratio"),
                pipelined_ips=fr.get("instances_per_sec_pipelined"),
                gated=False,
            )
            rows.append(row)
            if slowdown > threshold:
                warnings.append(row)
        for k in sorted(set(frows) - set(brows)):
            fr = frows[k]
            rows.append(dict(
                section=tag, cell=k[0], backend=k[1], batch=k[2],
                devices=k[3],
                baseline_ips=None,
                fresh_ips=fr.get("instances_per_sec"),
                slowdown=None,
                overlap_ratio=fr.get("overlap_ratio"),
                pipelined_ips=fr.get("instances_per_sec_pipelined"),
                gated=False,
            ))
    return dict(rows=rows, warnings=warnings, missing=missing)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="bench-regression gate (see module docstring)")
    ap.add_argument("baseline", help="committed BENCH_engine.baseline.json")
    ap.add_argument("fresh", help="freshly measured BENCH_engine.json")
    ap.add_argument("--threshold", type=float, default=float(
        os.environ.get("BENCH_REGRESSION_THRESHOLD", DEFAULT_THRESHOLD)))
    ap.add_argument("--out", default="BENCH_diff.json",
                    help="where to write the full diff artifact")
    ap.add_argument("--serve-baseline", default=None,
                    help="committed BENCH_serve.json (warn-only section)")
    ap.add_argument("--serve-fresh", default=None,
                    help="freshly measured BENCH_serve.json (warn-only)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    diff = compare(baseline, fresh, args.threshold)
    if args.serve_baseline and args.serve_fresh:
        with open(args.serve_baseline) as f:
            serve_base = json.load(f)
        with open(args.serve_fresh) as f:
            serve_fresh = json.load(f)
        diff["serve"] = compare_serve(serve_base, serve_fresh,
                                      args.threshold)
    with open(args.out, "w") as f:
        json.dump(diff, f, indent=2)

    for w in diff["missing"]:
        print(f"MISSING (warn): {w}")
    for c in diff["warnings"]:
        print(f"WARN (ungated {c['label']}): {c['graph']}/{c['metric']} "
              f"{c['baseline_us']:.1f} -> {c['fresh_us']:.1f}us "
              f"(x{c['ratio']})")
    for w in diff.get("descent_warnings", []):
        print(f"WARN (descent, ungated): {w}")
    if diff.get("descent"):
        d = diff["descent"]
        print(f"descent: speedup={d['fresh_speedup']} "
              f"(baseline {d['baseline_speedup']}) "
              f"descents={d['descents']} "
              f"plan_cache_hit_rate={d['plan_cache_hit_rate']}")
    if diff.get("serve"):
        sv = diff["serve"]
        for w in sv["missing"]:
            print(f"MISSING (serve, warn): {w}")
        for r in sv["warnings"]:
            print(f"WARN (serve, ungated): {r['section']}/{r['cell']}"
                  f"/{r['backend']}/b{r['batch']}/d{r['devices']} "
                  f"{r['baseline_ips']} -> {r['fresh_ips']} inst/s "
                  f"(x{r['slowdown']} slower)")
        for r in sv["rows"]:
            extra = (f" overlap={r['overlap_ratio']}"
                     if r.get("overlap_ratio") is not None else "")
            print(f"serve: {r['section']}/{r['cell']}/{r['backend']}"
                  f"/b{r['batch']}/d{r['devices']} "
                  f"{r['fresh_ips']} inst/s "
                  f"(baseline {r['baseline_ips']}){extra}")
    for c in diff["regressions"]:
        print(f"REGRESSION: {c['graph']}/{c['metric']}/{c['label']} "
              f"{c['baseline_us']:.1f} -> {c['fresh_us']:.1f}us "
              f"(x{c['ratio']} > {diff['threshold']}"
              f"{', normalized' if c['normalized'] else ''})")

    n_gated = sum(1 for c in diff["cells"] if c["gated"])
    print(f"# compared {len(diff['cells'])} cells ({n_gated} gated), "
          f"{len(diff['regressions'])} regressions, "
          f"{len(diff['warnings'])} warnings -> {args.out}")
    return 1 if diff["regressions"] else 0


if __name__ == "__main__":
    sys.exit(main())
